//! The 3PC mechanism family (paper §4, Algorithms 1–10).
//!
//! A *three point compressor* (Definition 4.1) is a map
//! `C_{h,y}(x)` satisfying
//!
//! ```text
//! E‖C_{h,y}(x) − x‖² ≤ (1 − A)‖h − y‖² + B‖x − y‖²        (6)
//! ```
//!
//! for constants `0 < A ≤ 1`, `B ≥ 0`. The communication mechanism (8)
//! instantiates it along the optimization path with `h = g_i^t`
//! (the previous transmitted estimate) and `y = ∇f_i(x^t)` (the previous
//! local gradient):
//!
//! ```text
//! g_i^{t+1} = C_{g_i^t, ∇f_i(x^t)}(∇f_i(x^{t+1}))          (8)/(13)
//! ```
//!
//! [`ThreePointMap`] is the stateless map; [`MechWorker`] is the stateful
//! per-worker wrapper that carries `h` and `y` and produces the wire
//! [`Update`]s the coordinator aggregates. Every method in Table 1 is a
//! `ThreePointMap` implementation in a submodule.

pub mod dcgd;
pub mod ef21;
pub mod lag;
pub mod marina;
pub mod schedule;
pub mod v1;
pub mod v2;
pub mod v3;
pub mod v4;

pub use dcgd::{Gd, NaiveDcgd};
pub use ef21::Ef21;
pub use lag::{Clag, Lag};
pub use marina::{Marina, V5};
pub use schedule::{
    parse_schedule, AdaptiveGrad, MechanismSchedule, Piecewise, PiecewiseEntry, RoundTelemetry,
    Static,
};
pub use v1::V1;
pub use v2::V2;
pub use v3::V3;
pub use v4::V4;

use crate::compressors::{CVec, Ctx, CtxInfo, MechScratch};
use crate::kernels;

/// The constants `(A, B)` of inequality (6), per Table 1 (with the
/// optimal `s*` already substituted where the method has a free `s`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MechParams {
    pub a: f64,
    pub b: f64,
}

impl MechParams {
    /// The ratio `B/A` appearing in every rate of Table 1.
    pub fn ratio(&self) -> f64 {
        if self.b == 0.0 {
            0.0
        } else {
            self.b / self.a
        }
    }
}

/// What a mechanism did in a round, in terms the server can apply and the
/// accountant can bill.
#[derive(Debug, Clone)]
pub enum Update {
    /// `g_i^{t+1} = g_i^t + inc` — the increment *is* the wire message
    /// (EF21-style). `bits` is its exact wire cost.
    Increment { inc: CVec, bits: u64 },
    /// `g_i^{t+1} = g` — state replaced; `bits` covers everything that
    /// had to cross the wire to let the server reconstruct it (LAG fire:
    /// the dense gradient; 3PCv2: both compressed messages; 3PCv1: the
    /// dense shift plus the compressed difference). `wire` is that same
    /// content as concrete messages, so a byte-level transport can
    /// serialize exactly what the accountant bills; the invariant
    /// `bits == wire.wire_bits()` (checked by the codec tests) ties the
    /// two together.
    Replace { g: Vec<f32>, bits: u64, wire: ReplaceWire },
    /// `g_i^{t+1} = g_i^t` — lazy-aggregation skip. Costs 0 payload bits
    /// (the 1-bit skip flag is charged by the protocol layer).
    Keep,
}

/// The messages a [`Update::Replace`] actually puts on the wire — enough
/// for the receiver to reconstruct the new state `g` from what it
/// already knows.
#[derive(Debug, Clone)]
pub enum ReplaceWire {
    /// The wire carries the dense new state itself (`g`): GD, a LAG
    /// fire, a MARINA/3PCv5 synchronisation round.
    Dense,
    /// `g = Σ parts`, materialised from zero: 3PCv1 (dense shift `y` +
    /// compressed difference), naive DCGD (the compressed gradient).
    Fresh(Vec<CVec>),
    /// `g = g_i^t + Σ parts`, relative to the previous state the server
    /// mirrors: 3PCv2 (`Q(x−y)` then `C(x−b)`), 3PCv3 over an
    /// increment-style inner mechanism.
    FromPrev(Vec<CVec>),
}

impl ReplaceWire {
    /// Declared wire cost of the decomposition (must equal the update's
    /// billed `bits`; `Dense` is billed per the carried state's length,
    /// so it takes the dimension from the caller).
    pub fn wire_bits(&self, dim: usize) -> u64 {
        match self {
            ReplaceWire::Dense => 32 * dim as u64,
            ReplaceWire::Fresh(parts) | ReplaceWire::FromPrev(parts) => {
                // lint:allow(float-fold): integer bit accounting
                parts.iter().map(|p| p.wire_bits()).sum()
            }
        }
    }

}

// Receiver-side reconstruction lives in one place only:
// `WireUpdate::new_state` (coordinator::protocol), which the Framed
// transport drives after decoding.

/// A three point compressor: the stateless map of Definition 4.1.
///
/// Implementors provide [`ThreePointMap::apply_into`], the
/// scratch-buffer form driven by [`MechWorker`]'s recycled update slot;
/// [`ThreePointMap::apply`] stays available as a default-impl wrapper
/// for callers that want an owned [`Update`].
pub trait ThreePointMap: Send + Sync {
    fn name(&self) -> String;

    /// The canonical parseable spec of this map: feeding it back
    /// through [`parse_mechanism`] reconstructs an equivalent map. This
    /// is what downlink `MechSwitch` directives carry so a *remote*
    /// worker (socket transport) can instantiate the mechanism from
    /// wire bytes alone — display [`name`](ThreePointMap::name)s are
    /// for humans and traces, specs are for peers.
    fn spec(&self) -> String;

    /// Apply `C_{h,y}(x)`, writing what crossed the wire into `out`.
    /// Callers pass a reclaimed slot (its previous buffers already
    /// salvaged into `ctx`'s scratch pool via [`recycle_update`]); the
    /// mechanism draws every diff/residual/state buffer from the pool,
    /// so with a pool attached a steady-state round allocates nothing.
    fn apply_into(&self, h: &[f32], y: &[f32], x: &[f32], ctx: &mut Ctx<'_>, out: &mut Update);

    /// Allocating convenience wrapper over
    /// [`ThreePointMap::apply_into`].
    fn apply(&self, h: &[f32], y: &[f32], x: &[f32], ctx: &mut Ctx<'_>) -> Update {
        let mut out = Update::Keep;
        self.apply_into(h, y, x, ctx, &mut out);
        out
    }

    /// The `(A, B)` certificate of inequality (6). `None` for baselines
    /// that are *not* 3PC compressors (naive DCGD).
    fn params(&self, info: &CtxInfo) -> Option<MechParams>;

    /// Whether the method requires a round-shared coin/permutation (the
    /// coordinator threads a per-round seed through `Ctx` regardless;
    /// this is informational).
    fn uses_shared_randomness(&self) -> bool {
        false
    }
}

impl MechScratch {
    /// Salvage every heap buffer of a spent [`Update`] back into the
    /// pool: the state vector of a `Replace`, each wire part's
    /// index/value buffers, and the decomposition container itself.
    pub fn reclaim_update(&mut self, u: Update) {
        match u {
            Update::Keep => {}
            Update::Increment { inc, .. } => self.reclaim_cvec(inc),
            Update::Replace { g, wire, .. } => {
                self.put_f32(g);
                match wire {
                    ReplaceWire::Dense => {}
                    ReplaceWire::Fresh(parts) | ReplaceWire::FromPrev(parts) => {
                        self.put_parts(parts)
                    }
                }
            }
        }
    }
}

/// Reset `slot` to [`Update::Keep`], salvaging its buffers into `ctx`'s
/// scratch pool (a no-op salvage when no pool is attached). Mechanism
/// implementations call this before writing a fresh update into a slot
/// they did not receive pre-reclaimed.
pub fn recycle_update(ctx: &mut Ctx<'_>, slot: &mut Update) {
    let old = std::mem::replace(slot, Update::Keep);
    if let Some(s) = ctx.scratch_mut() {
        s.reclaim_update(old);
    }
}

/// Materialise the new state `g_i^{t+1}` an [`Update`] encodes.
pub fn apply_update(h: &[f32], u: &Update) -> Vec<f32> {
    match u {
        Update::Increment { inc, .. } => {
            let mut g = h.to_vec();
            inc.add_into(&mut g);
            g
        }
        Update::Replace { g, .. } => g.clone(),
        Update::Keep => h.to_vec(),
    }
}

/// Payload bits of an update.
pub fn update_bits(u: &Update) -> u64 {
    match u {
        Update::Increment { bits, .. } | Update::Replace { bits, .. } => *bits,
        Update::Keep => 0,
    }
}

/// Stateful per-worker wrapper: owns `h = g_i^t` and `y = ∇f_i(x^t)` and
/// advances them per round (Algorithm 1 lines 6–8). Also owns the
/// round's recycled output slot and the [`MechScratch`] buffer pool, so
/// at steady state [`MechWorker::round_acc`] performs zero heap
/// allocations for allocation-free mechanisms (EF21/CLAG over Top-K —
/// pinned by the `alloc_steady` regression test).
pub struct MechWorker {
    map: std::sync::Arc<dyn ThreePointMap>,
    /// `g_i^t` — the state mirrored by the server through the updates.
    h: Vec<f32>,
    /// `y = ∇f_i(x^t)` — the previous local gradient.
    y: Vec<f32>,
    /// The current round's update; its buffers are salvaged into
    /// `scratch` at the start of the next round.
    update: Update,
    /// Buffer pool lent to the mechanism + compressors each round.
    scratch: MechScratch,
}

impl MechWorker {
    /// `g0` is the starting vector `g_i^0` (known to server and worker);
    /// `grad0 = ∇f_i(x^0)`.
    pub fn new(map: std::sync::Arc<dyn ThreePointMap>, g0: Vec<f32>, grad0: Vec<f32>) -> MechWorker {
        assert_eq!(g0.len(), grad0.len());
        MechWorker { map, h: g0, y: grad0, update: Update::Keep, scratch: MechScratch::new() }
    }

    pub fn g(&self) -> &[f32] {
        &self.h
    }

    /// The update produced by the most recent round, borrowed from the
    /// recycled slot (valid until the next `round`/`round_acc` call).
    pub fn last_update(&self) -> &Update {
        &self.update
    }

    pub fn map_name(&self) -> String {
        self.map.name()
    }

    /// Canonical parseable spec of the installed map (see
    /// [`ThreePointMap::spec`]).
    pub fn map_spec(&self) -> String {
        self.map.spec()
    }

    /// Install a new three point compressor mid-run (the schedule axis,
    /// [`schedule::MechanismSchedule`]). `h = g_i^t` and
    /// `y = ∇f_i(x^t)` carry over unchanged: the server mirrors `h`
    /// through the update stream regardless of which map produced it,
    /// and `y` is the worker's own previous local gradient — both are
    /// exactly the state the mechanism recursion (8) needs, so
    /// EF21-style memory survives the switch and the next update is
    /// produced (and billed) under the new map.
    pub fn swap_map(&mut self, map: std::sync::Arc<dyn ThreePointMap>) {
        self.map = map;
    }

    /// One round: consume `∇f_i(x^{t+1})`, emit the wire update, advance
    /// internal state. Returns `(update, ‖g_i^{t+1} − ∇f_i(x^{t+1})‖²)`;
    /// the second term is this worker's contribution to `G^t` (Eq. 15),
    /// which the rate-verification experiments track. (Compat wrapper:
    /// the hot path is [`Self::round_acc`] + [`Self::last_update`],
    /// which never clones the update.)
    pub fn round(&mut self, grad_new: &[f32], ctx: &mut Ctx<'_>) -> (Update, f64) {
        let mut unused = Vec::new();
        let gerr = self.round_acc(grad_new, ctx, &mut unused);
        (self.update.clone(), gerr)
    }

    /// Like [`Self::round`], but the update lands in the recycled slot
    /// ([`Self::last_update`]) and this worker's delta
    /// `g_i^{t+1} − g_i^t` is folded into `delta_acc` (the transport's
    /// per-thread f64 partial sum) without materialising intermediate
    /// copies. `delta_acc` may be empty (no accumulation) or of length
    /// `d`. Returns the `G^t` contribution.
    pub fn round_acc(
        &mut self,
        grad_new: &[f32],
        ctx: &mut Ctx<'_>,
        delta_acc: &mut Vec<f64>,
    ) -> f64 {
        // Salvage last round's buffers, then run the map with the pool
        // attached — the whole apply is allocation-free at steady state.
        // The shard handle rides along: every O(d) loop below (and
        // inside the map) may fan out over idle pool threads with
        // bit-identical results (kernels fixed-chunk contract).
        let sh = ctx.shards();
        // A wire sink attached by the transport transfers into the
        // scratched context so the map can fuse compress + encode (a
        // map that doesn't opt in simply leaves the buffer empty and
        // the transport falls back to the generic encoder).
        let wire = ctx.take_wire();
        let prev = std::mem::replace(&mut self.update, Update::Keep);
        self.scratch.reclaim_update(prev);
        let mut scratched =
            Ctx::with_scratch(ctx.info, &mut *ctx.rng, ctx.round_seed, &mut self.scratch)
                .sharded(sh);
        if let Some((coding, buf)) = wire {
            scratched = scratched.with_wire(coding, buf);
        }
        self.map.apply_into(&self.h, &self.y, grad_new, &mut scratched, &mut self.update);
        drop(scratched);
        if !delta_acc.is_empty() {
            debug_assert_eq!(delta_acc.len(), self.h.len());
            match &self.update {
                Update::Keep => {}
                Update::Increment { inc, .. } => match inc {
                    CVec::Zero { .. } => {}
                    CVec::Dense(v) => kernels::fold_f64(sh, delta_acc, v),
                    CVec::Sparse { idx, val, .. } => {
                        for (&i, &v) in idx.iter().zip(val) {
                            delta_acc[i as usize] += v as f64;
                        }
                    }
                },
                Update::Replace { g, .. } => kernels::fold_delta_f64(sh, delta_acc, g, &self.h),
            }
        }
        // Advance h in place (perf: `apply_update` would clone a fresh
        // d-vector per worker-round — ~10 MB/round at n=100, d=25088;
        // see EXPERIMENTS.md §Perf iteration 1).
        match &self.update {
            Update::Keep => {}
            Update::Increment { inc, .. } => inc.add_into_sh(sh, &mut self.h),
            Update::Replace { g, .. } => kernels::copy(sh, g, &mut self.h),
        }
        kernels::copy(sh, grad_new, &mut self.y);
        kernels::dist_sq(sh, &self.h, grad_new)
    }
}

/// Parse a mechanism spec into a factory shared across workers.
///
/// Grammar (`<c>` = contractive spec, `<q>` = unbiased spec, see
/// [`crate::compressors`]):
///
/// * `gd` — exact gradients (gradient descent);
/// * `dcgd:<c>` — naive DCGD with a contractive compressor (divergence
///   baseline; not a 3PC compressor);
/// * `ef21:<c>` — Algorithm 2;
/// * `lag:<ζ>` — Algorithm 3;
/// * `clag:<c>:<ζ>` — Algorithm 4;
/// * `v1:<c>` — Algorithm 5;
/// * `v2:<q>:<c>` — Algorithm 6;
/// * `v3:<inner-spec>;<c>` — Algorithm 7 (inner spec is any 3PC spec);
/// * `v4:<c2>:<c1>` — Algorithm 8;
/// * `v5:<p>:<c>` — Algorithm 9 (biased MARINA);
/// * `marina:<p>:<q>` — Algorithm 10.
pub fn parse_mechanism(spec: &str) -> anyhow::Result<std::sync::Arc<dyn ThreePointMap>> {
    use crate::compressors::{parse_contractive, parse_unbiased};
    let s = spec.trim();
    if s == "gd" {
        return Ok(std::sync::Arc::new(Gd));
    }
    if let Some(rest) = s.strip_prefix("dcgd:") {
        return Ok(std::sync::Arc::new(NaiveDcgd::new(parse_contractive(rest)?)));
    }
    if let Some(rest) = s.strip_prefix("ef21:") {
        return Ok(std::sync::Arc::new(Ef21::new(parse_contractive(rest)?)));
    }
    if let Some(rest) = s.strip_prefix("lag:") {
        return Ok(std::sync::Arc::new(Lag::new(rest.parse()?)));
    }
    if let Some(rest) = s.strip_prefix("clag:") {
        let (c, z) = rest
            .rsplit_once(':')
            .ok_or_else(|| anyhow::anyhow!("clag spec needs `clag:<c>:<zeta>`"))?;
        return Ok(std::sync::Arc::new(Clag::new(parse_contractive(c)?, z.parse()?)));
    }
    if let Some(rest) = s.strip_prefix("v1:") {
        return Ok(std::sync::Arc::new(V1::new(parse_contractive(rest)?)));
    }
    if let Some(rest) = s.strip_prefix("v2:") {
        let (q, c) = rest
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("v2 spec needs `v2:<q>:<c>`"))?;
        return Ok(std::sync::Arc::new(V2::new(parse_unbiased(q)?, parse_contractive(c)?)));
    }
    if let Some(rest) = s.strip_prefix("v3:") {
        let (inner, c) = rest
            .rsplit_once(';')
            .ok_or_else(|| anyhow::anyhow!("v3 spec needs `v3:<inner-3pc-spec>;<c>`"))?;
        let inner_map = parse_mechanism(inner)?;
        return Ok(std::sync::Arc::new(V3::new(inner_map, parse_contractive(c)?)));
    }
    if let Some(rest) = s.strip_prefix("v4:") {
        let (c2, c1) = rest
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("v4 spec needs `v4:<c2>:<c1>`"))?;
        return Ok(std::sync::Arc::new(V4::new(parse_contractive(c2)?, parse_contractive(c1)?)));
    }
    if let Some(rest) = s.strip_prefix("v5:") {
        let (p, c) = rest
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("v5 spec needs `v5:<p>:<c>`"))?;
        return Ok(std::sync::Arc::new(V5::new(p.parse()?, parse_contractive(c)?)));
    }
    if let Some(rest) = s.strip_prefix("marina:") {
        let (p, q) = rest
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("marina spec needs `marina:<p>:<q>`"))?;
        return Ok(std::sync::Arc::new(Marina::new(p.parse()?, parse_unbiased(q)?)));
    }
    anyhow::bail!("unknown mechanism spec '{spec}'")
}

#[cfg(test)]
pub(crate) mod proptests {
    //! Shared property-test driver: empirically checks inequality (6)
    //! for a `ThreePointMap` with its declared `(A, B)` over randomized
    //! triples `(h, y, x)`. Randomized maps are averaged over draws.

    use super::*;
    use crate::testkit::gen;
    use crate::util::linalg::dist_sq;
    use crate::util::rng::Pcg64;

    pub fn check_3pc_inequality(
        map: &dyn ThreePointMap,
        info: CtxInfo,
        cases: usize,
        draws: usize,
        seed: u64,
        tol: f64,
    ) {
        let params = map
            .params(&info)
            .unwrap_or_else(|| panic!("{} has no (A,B)", map.name()));
        assert!(params.a > 0.0 && params.a <= 1.0, "A out of range: {params:?}");
        assert!(params.b >= 0.0, "B negative: {params:?}");
        let mut meta = Pcg64::seed(seed);
        for case in 0..cases {
            let d = info.dim;
            let y = gen::vector(&mut meta, d, 1.0);
            // h near y sometimes (converged regime) and far sometimes.
            let spread = if case % 2 == 0 { 0.1 } else { 3.0 };
            let h: Vec<f32> = y
                .iter()
                .map(|&v| v + meta.normal_ms(0.0, spread) as f32)
                .collect();
            let x: Vec<f32> = y
                .iter()
                .map(|&v| v + meta.normal_ms(0.0, 0.7) as f32)
                .collect();
            let mut acc = 0.0;
            for t in 0..draws {
                let mut rng = Pcg64::new(seed ^ 0x77, (case * draws + t) as u64);
                let mut ctx = Ctx::new(info, &mut rng, (case * draws + t) as u64);
                let u = map.apply(&h, &y, &x, &mut ctx);
                let g = apply_update(&h, &u);
                acc += dist_sq(&g, &x);
            }
            let lhs = acc / draws as f64;
            let rhs = (1.0 - params.a) * dist_sq(&h, &y) + params.b * dist_sq(&x, &y);
            assert!(
                lhs <= rhs * (1.0 + tol) + 1e-9,
                "{}: case {case}: E‖C_h,y(x)−x‖²={lhs:.6} > (1−A)‖h−y‖²+B‖x−y‖²={rhs:.6} (A={}, B={})",
                map.name(),
                params.a,
                params.b
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn parse_all_specs() {
        for s in [
            "gd",
            "dcgd:top4",
            "ef21:top4",
            "lag:4.0",
            "clag:top4:2.0",
            "v1:top4",
            "v2:rand4:top4",
            "v3:ef21:top4;top2",
            "v4:top4:top2",
            "v5:0.25:top4",
            "marina:0.25:rand4",
        ] {
            assert!(parse_mechanism(s).is_ok(), "spec {s}");
        }
        assert!(parse_mechanism("bogus").is_err());
        assert!(parse_mechanism("v2:rand4").is_err());
    }

    #[test]
    fn mechanism_specs_roundtrip_through_parser() {
        // `spec()` is the wire form of a mechanism (MechSwitch
        // directives carry it so remote workers can reconstruct the
        // map): parse → spec → parse must land on an equivalent map.
        for s in [
            "gd",
            "dcgd:top4",
            "ef21:top4",
            "lag:4.0",
            "clag:top4:2.0",
            "v1:top4",
            "v2:rand4:top4",
            "v3:ef21:top4;top2",
            "v4:top4:top2",
            "v5:0.25:top4",
            "marina:0.25:rand4",
            "ef21:cperm*crand8",
            "clag:scaled-natural:2.0",
        ] {
            let map = parse_mechanism(s).unwrap();
            let back = parse_mechanism(&map.spec())
                .unwrap_or_else(|e| panic!("{s}: spec '{}' unparseable: {e}", map.spec()));
            assert_eq!(back.name(), map.name(), "{s} → {}", map.spec());
        }
    }

    #[test]
    fn mechworker_tracks_state() {
        let map = parse_mechanism("ef21:top1").unwrap();
        let g0 = vec![0.0f32; 3];
        let grad0 = vec![1.0f32, 0.5, 0.25];
        let mut w = MechWorker::new(map, g0, grad0);
        let mut rng = Pcg64::seed(0);
        let grad1 = vec![2.0f32, 0.1, 0.1];
        let info = CtxInfo::single(3);
        let mut ctx = Ctx::new(info, &mut rng, 1);
        let (u, gerr) = w.round(&grad1, &mut ctx);
        // EF21 with Top-1 from h=0: C(grad1 − 0) keeps coordinate 0.
        assert_eq!(w.g(), &[2.0, 0.0, 0.0]);
        assert!(matches!(u, Update::Increment { .. }));
        assert!((gerr - (0.01f64 + 0.01)).abs() < 1e-9);
    }

    #[test]
    fn ratio_handles_zero_b() {
        assert_eq!(MechParams { a: 1.0, b: 0.0 }.ratio(), 0.0);
    }

    #[test]
    fn swap_map_carries_h_and_y_over() {
        let map = parse_mechanism("ef21:top1").unwrap();
        let mut w = MechWorker::new(map, vec![0.0f32; 3], vec![1.0f32, 0.5, 0.25]);
        let info = CtxInfo::single(3);
        let mut rng = Pcg64::seed(0);
        let mut ctx = Ctx::new(info, &mut rng, 1);
        w.round(&[2.0f32, 0.1, 0.1], &mut ctx);
        assert_eq!(w.g(), &[2.0, 0.0, 0.0]);
        // Switch to GD mid-run: the accumulated h survives the swap, and
        // the next round runs (and bills) under the new map.
        w.swap_map(parse_mechanism("gd").unwrap());
        assert_eq!(w.g(), &[2.0, 0.0, 0.0], "h must survive the swap");
        assert_eq!(w.map_name(), "GD");
        let mut ctx = Ctx::new(info, &mut rng, 2);
        let (u, gerr) = w.round(&[1.0f32, 1.0, 1.0], &mut ctx);
        assert!(matches!(u, Update::Replace { .. }));
        assert_eq!(w.g(), &[1.0, 1.0, 1.0]);
        assert_eq!(gerr, 0.0);
    }
}
