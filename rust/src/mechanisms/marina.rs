//! MARINA (Algorithm 10; Gorbunov et al. 2021) and its biased variant
//! 3PCv5 / "Biased MARINA" (Algorithm 9).
//!
//! Both flip a **round-shared** coin `c_t ~ Be(p)`:
//!
//! * `c_t = 1` → every worker transmits the exact gradient (dense);
//! * `c_t = 0` → 3PCv5 sends `g = h + C(x − y)` (Lemma C.23, optimal s*:
//!   `A = 1 − √(1−p)`, `B = (1−p)(1−α)/(1−√(1−p))`), MARINA sends
//!   `g = h + Q(x − y)` (Lemma D.1: `A = p`, `B = (1−p)ω/n` — note the
//!   1/n: MARINA's certificate is for the *aggregate* error `G^t =
//!   ‖g^t − ∇f(x^t)‖²`, which inequality (16) covers; per Table 1 it does
//!   not satisfy the per-worker definition (6)).

use super::{recycle_update, MechParams, ReplaceWire, ThreePointMap, Update};
use crate::compressors::{Bernoulli, CVec, Contractive, Ctx, CtxInfo, Unbiased};

/// 3PCv5: biased MARINA (Algorithm 9).
pub struct V5 {
    coin: Bernoulli,
    c: Box<dyn Contractive>,
}

impl V5 {
    pub fn new(p: f64, c: Box<dyn Contractive>) -> V5 {
        V5 { coin: Bernoulli::shared(p), c }
    }

    pub fn p(&self) -> f64 {
        self.coin.p
    }
}

impl ThreePointMap for V5 {
    fn name(&self) -> String {
        format!("3PCv5(p={},{})", self.coin.p, self.c.name())
    }

    fn spec(&self) -> String {
        format!("v5:{}:{}", self.coin.p, self.c.spec())
    }

    fn apply_into(&self, _h: &[f32], y: &[f32], x: &[f32], ctx: &mut Ctx<'_>, out: &mut Update) {
        recycle_update(ctx, out);
        if self.coin.flip(ctx) {
            // Full synchronisation round: dense gradient on the wire.
            let g = ctx.take_f32_copy(x);
            *out = Update::Replace { g, bits: 32 * x.len() as u64, wire: ReplaceWire::Dense };
            return;
        }
        // g = h + C(x − y): compress the *gradient difference*
        // (the increment is relative to h, applied by the wrapper).
        let mut diff = ctx.take_f32_zeroed(x.len());
        crate::kernels::diff(ctx.shards(), x, y, &mut diff);
        let mut inc = CVec::Zero { dim: 0 };
        self.c.compress_into(&diff, ctx, &mut inc);
        ctx.put_f32(diff);
        let bits = inc.wire_bits();
        *out = Update::Increment { inc, bits };
    }

    fn params(&self, info: &CtxInfo) -> Option<MechParams> {
        let p = self.coin.p;
        let alpha = self.c.alpha(info);
        if p >= 1.0 {
            return Some(MechParams { a: 1.0, b: 0.0 });
        }
        let root = (1.0 - p).sqrt();
        Some(MechParams {
            a: 1.0 - root,
            b: (1.0 - p) * (1.0 - alpha) / (1.0 - root),
        })
    }

    fn uses_shared_randomness(&self) -> bool {
        true
    }
}

/// MARINA (Algorithm 10): unbiased compressor on the gradient difference.
pub struct Marina {
    coin: Bernoulli,
    q: Box<dyn Unbiased>,
}

impl Marina {
    pub fn new(p: f64, q: Box<dyn Unbiased>) -> Marina {
        Marina { coin: Bernoulli::shared(p), q }
    }

    pub fn p(&self) -> f64 {
        self.coin.p
    }
}

impl ThreePointMap for Marina {
    fn name(&self) -> String {
        format!("MARINA(p={},{})", self.coin.p, self.q.name())
    }

    fn spec(&self) -> String {
        format!("marina:{}:{}", self.coin.p, self.q.spec())
    }

    fn apply_into(&self, _h: &[f32], y: &[f32], x: &[f32], ctx: &mut Ctx<'_>, out: &mut Update) {
        recycle_update(ctx, out);
        if self.coin.flip(ctx) {
            let g = ctx.take_f32_copy(x);
            *out = Update::Replace { g, bits: 32 * x.len() as u64, wire: ReplaceWire::Dense };
            return;
        }
        let mut diff = ctx.take_f32_zeroed(x.len());
        crate::kernels::diff(ctx.shards(), x, y, &mut diff);
        let mut inc = CVec::Zero { dim: 0 };
        self.q.compress_into(&diff, ctx, &mut inc);
        ctx.put_f32(diff);
        let bits = inc.wire_bits();
        *out = Update::Increment { inc, bits };
    }

    /// Aggregate-level certificate (Lemma D.1): A = p, B = (1−p)ω/n.
    fn params(&self, info: &CtxInfo) -> Option<MechParams> {
        let p = self.coin.p;
        let omega = self.q.omega(info);
        let n = info.n_workers.max(1) as f64;
        Some(MechParams { a: p, b: (1.0 - p) * omega / n })
    }

    fn uses_shared_randomness(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::{RandK, TopK};
    use crate::mechanisms::proptests::check_3pc_inequality;
    use crate::util::rng::Pcg64;

    #[test]
    fn v5_constants_lemma_c23() {
        let info = CtxInfo::single(16);
        // p = 3/4 → √(1−p) = 1/2 → A = 1/2; α = 1/2 → B = (1/4·1/2)/(1/2) = 1/4.
        let v5 = V5::new(0.75, Box::new(TopK::new(8)));
        let p = v5.params(&info).unwrap();
        assert!((p.a - 0.5).abs() < 1e-12);
        assert!((p.b - 0.25).abs() < 1e-12);
    }

    #[test]
    fn marina_constants_lemma_d1() {
        let info = CtxInfo { dim: 16, n_workers: 4, worker_id: 0 };
        // ω = 16/4 − 1 = 3, p = 0.5 → A = 0.5, B = 0.5·3/4 = 0.375.
        let m = Marina::new(0.5, Box::new(RandK::new(4)));
        let p = m.params(&info).unwrap();
        assert!((p.a - 0.5).abs() < 1e-12);
        assert!((p.b - 0.375).abs() < 1e-12);
    }

    #[test]
    fn shared_coin_synchronises_workers() {
        // All workers must agree on dense-vs-compressed within a round.
        let v5 = V5::new(0.5, Box::new(TopK::new(1)));
        let h = [0.0f32; 4];
        let y = [0.5f32; 4];
        let x = [1.0f32, 2.0, 3.0, 4.0];
        for round in 0..20u64 {
            let mut kinds = Vec::new();
            for w in 0..3usize {
                let mut rng = Pcg64::new(w as u64 + 100, 7);
                let info = CtxInfo { dim: 4, n_workers: 3, worker_id: w };
                let mut ctx = Ctx::new(info, &mut rng, round);
                let u = v5.apply(&h, &y, &x, &mut ctx);
                kinds.push(matches!(u, Update::Replace { .. }));
            }
            assert!(kinds.iter().all(|&k| k == kinds[0]), "round {round}: {kinds:?}");
        }
    }

    #[test]
    fn full_round_bills_dense() {
        let v5 = V5::new(1.0, Box::new(TopK::new(1)));
        let mut rng = Pcg64::seed(0);
        let info = CtxInfo::single(4);
        let u = v5.apply(&[0.0; 4], &[0.0; 4], &[1.0; 4], &mut Ctx::new(info, &mut rng, 0));
        assert_eq!(super::super::update_bits(&u), 128);
    }

    #[test]
    fn prop_3pc_inequality_v5() {
        let map = V5::new(0.4, Box::new(TopK::new(3)));
        check_3pc_inequality(&map, CtxInfo::single(9), 20, 4_000, 91, 0.08);
    }

    /// MARINA's certificate is aggregate-level with the 1/n factor, so the
    /// per-worker check uses n = 1 (where Lemma D.1 reduces to the
    /// per-worker statement).
    #[test]
    fn prop_3pc_inequality_marina_n1() {
        let map = Marina::new(0.4, Box::new(RandK::new(3)));
        check_3pc_inequality(&map, CtxInfo::single(9), 20, 4_000, 92, 0.08);
    }
}
