//! EF21 (Algorithm 2; Richtárik et al. 2021) as a 3PC compressor:
//!
//! `C_{h,y}(x) = h + C(x − h)`                         (10)
//!
//! Lemma C.1/C.3: satisfies (6) with the optimal `s* = −1 + 1/√(1−α)`
//! giving `A = 1 − √(1−α)` and `B = (1−α)/(1−√(1−α))`, hence
//! `B/A = (1−α)/(1−√(1−α))² ≤ 4(1−α)/α²`.

use super::{recycle_update, MechParams, ThreePointMap, Update};
use crate::compressors::{CVec, Contractive, Ctx, CtxInfo};

pub struct Ef21 {
    c: Box<dyn Contractive>,
}

impl Ef21 {
    pub fn new(c: Box<dyn Contractive>) -> Ef21 {
        Ef21 { c }
    }

    /// Table-1 constants for a given contraction parameter α.
    pub fn params_for_alpha(alpha: f64) -> MechParams {
        if alpha >= 1.0 {
            // Identity compressor: exact, A = 1, B = 0 (GD).
            return MechParams { a: 1.0, b: 0.0 };
        }
        let root = (1.0 - alpha).sqrt();
        MechParams { a: 1.0 - root, b: (1.0 - alpha) / (1.0 - root) }
    }
}

impl ThreePointMap for Ef21 {
    fn name(&self) -> String {
        format!("EF21({})", self.c.name())
    }

    fn spec(&self) -> String {
        format!("ef21:{}", self.c.spec())
    }

    fn apply_into(&self, h: &[f32], _y: &[f32], x: &[f32], ctx: &mut Ctx<'_>, out: &mut Update) {
        // residual = x − h; message = C(residual); g_new = h + message.
        // Perf (§Perf iteration 3): the residual and the compressed
        // message's buffers all come from the worker's scratch pool —
        // this replaced the earlier thread-local residual hack with the
        // uniform `apply_into`/`compress_into` mechanism, making the
        // whole apply allocation-free at steady state.
        recycle_update(ctx, out);
        let mut residual = ctx.take_f32_zeroed(x.len());
        crate::kernels::diff(ctx.shards(), x, h, &mut residual);
        let mut inc = CVec::Zero { dim: 0 };
        // When a transport attached a wire sink, fuse: the compressor
        // encodes the increment's frame bytes in the same pass that
        // produces it (Top-K's override — identical bytes to the
        // generic encoder; see `Contractive::compress_encode_into`).
        if let Some((coding, wire)) = ctx.take_wire() {
            self.c.compress_encode_into(&residual, ctx, coding, &mut inc, wire);
        } else {
            self.c.compress_into(&residual, ctx, &mut inc);
        }
        ctx.put_f32(residual);
        let bits = inc.wire_bits();
        *out = Update::Increment { inc, bits };
    }

    fn params(&self, info: &CtxInfo) -> Option<MechParams> {
        Some(Self::params_for_alpha(self.c.alpha(info)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::{CRandK, TopK};
    use crate::mechanisms::proptests::check_3pc_inequality;

    #[test]
    fn table1_constants() {
        // α = 3/4 → √(1−α) = 1/2 → A = 1/2, B = (1/4)/(1/2) = 1/2.
        let p = Ef21::params_for_alpha(0.75);
        assert!((p.a - 0.5).abs() < 1e-12);
        assert!((p.b - 0.5).abs() < 1e-12);
        // Identity: GD limit.
        let p = Ef21::params_for_alpha(1.0);
        assert_eq!(p, MechParams { a: 1.0, b: 0.0 });
    }

    #[test]
    fn prop_3pc_inequality_topk() {
        let map = Ef21::new(Box::new(TopK::new(3)));
        check_3pc_inequality(&map, CtxInfo::single(12), 40, 1, 100, 1e-9);
    }

    #[test]
    fn prop_3pc_inequality_crandk() {
        let map = Ef21::new(Box::new(CRandK::new(4)));
        check_3pc_inequality(&map, CtxInfo::single(10), 25, 3_000, 200, 0.06);
    }

    #[test]
    fn message_is_sparse() {
        use crate::util::rng::Pcg64;
        let map = Ef21::new(Box::new(TopK::new(2)));
        let mut rng = Pcg64::seed(0);
        let info = CtxInfo::single(6);
        let mut ctx = Ctx::new(info, &mut rng, 0);
        let u = map.apply(&[0.0; 6], &[0.0; 6], &[5.0, 1.0, -9.0, 0.0, 0.0, 0.1], &mut ctx);
        match u {
            Update::Increment { inc, bits } => {
                assert_eq!(inc.nnz(), 2);
                assert_eq!(bits, inc.wire_bits());
            }
            other => panic!("expected increment, got {other:?}"),
        }
    }
}
