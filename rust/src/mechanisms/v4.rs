//! 3PCv4 (Algorithm 8) — two stacked *biased* (contractive) compressors:
//!
//! `C_{h,y}(x) = b + C₁(x − b)` where `b = h + C₂(x − h)`    (62)
//!
//! Lemma C.20: with ᾱ = 1 − (1−α₁)(1−α₂) and the optimal s*,
//! `A = 1 − √(1−ᾱ)`, `B = (1−ᾱ)/(1−√(1−ᾱ))` — i.e. EF21's constants at
//! the *boosted* contraction level ᾱ.
//!
//! Both messages (`C₂(x−h)` and `C₁(x−b)`) are billed. With
//! Top-K₁/Top-K₂ on the sparse quadratic suite this frequently collapses
//! to EF21 behaviour (Figures 14–15), which the experiments reproduce.

use super::{ef21::Ef21, recycle_update, MechParams, ReplaceWire, ThreePointMap, Update};
use crate::compressors::{CVec, Contractive, Ctx, CtxInfo};

pub struct V4 {
    /// The inner compressor C₂ (applied to x − h).
    c2: Box<dyn Contractive>,
    /// The outer compressor C₁ (applied to the residual x − b).
    c1: Box<dyn Contractive>,
}

impl V4 {
    pub fn new(c2: Box<dyn Contractive>, c1: Box<dyn Contractive>) -> V4 {
        V4 { c2, c1 }
    }
}

impl ThreePointMap for V4 {
    fn name(&self) -> String {
        format!("3PCv4({},{})", self.c2.name(), self.c1.name())
    }

    fn spec(&self) -> String {
        format!("v4:{}:{}", self.c2.spec(), self.c1.spec())
    }

    fn apply_into(&self, h: &[f32], _y: &[f32], x: &[f32], ctx: &mut Ctx<'_>, out: &mut Update) {
        recycle_update(ctx, out);
        let sh = ctx.shards();
        let d = x.len();
        let mut residual = ctx.take_f32_zeroed(d);
        crate::kernels::diff(sh, x, h, &mut residual);
        let mut m2 = CVec::Zero { dim: 0 };
        self.c2.compress_into(&residual, ctx, &mut m2);
        let mut b = ctx.take_f32_copy(h);
        m2.add_into_sh(sh, &mut b);
        crate::kernels::diff(sh, x, &b, &mut residual);
        let mut m1 = CVec::Zero { dim: 0 };
        self.c1.compress_into(&residual, ctx, &mut m1);
        ctx.put_f32(residual);
        let bits = m2.wire_bits() + m1.wire_bits();
        let mut g = b;
        m1.add_into_sh(sh, &mut g);
        // g = h + C₂(x−h) + C₁(x−b): both messages relative to the
        // server's mirror of h.
        let mut parts = ctx.take_parts();
        parts.push(m2);
        parts.push(m1);
        *out = Update::Replace { g, bits, wire: ReplaceWire::FromPrev(parts) };
    }

    fn params(&self, info: &CtxInfo) -> Option<MechParams> {
        let a1 = self.c1.alpha(info);
        let a2 = self.c2.alpha(info);
        let abar = 1.0 - (1.0 - a1) * (1.0 - a2);
        Some(Ef21::params_for_alpha(abar))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::{CRandK, TopK};
    use crate::mechanisms::proptests::check_3pc_inequality;

    #[test]
    fn constants_match_lemma_c20() {
        let info = CtxInfo::single(16);
        // α₁ = α₂ = 1/2 → ᾱ = 3/4 → A = 1/2, B = 1/2.
        let v4 = V4::new(Box::new(TopK::new(8)), Box::new(TopK::new(8)));
        let p = v4.params(&info).unwrap();
        assert!((p.a - 0.5).abs() < 1e-12);
        assert!((p.b - 0.5).abs() < 1e-12);
    }

    #[test]
    fn two_topk_passes_capture_2k_coords() {
        use crate::util::rng::Pcg64;
        let v4 = V4::new(Box::new(TopK::new(2)), Box::new(TopK::new(2)));
        let mut rng = Pcg64::seed(0);
        let info = CtxInfo::single(6);
        let x = [10.0f32, 9.0, 8.0, 7.0, 0.1, 0.0];
        let u = v4.apply(&[0.0; 6], &[0.0; 6], &x, &mut Ctx::new(info, &mut rng, 0));
        match u {
            Update::Replace { g, .. } => {
                // first pass grabs {10, 9}, second pass {8, 7}.
                assert_eq!(g, vec![10.0, 9.0, 8.0, 7.0, 0.0, 0.0]);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn prop_3pc_inequality_topk() {
        let map = V4::new(Box::new(TopK::new(2)), Box::new(TopK::new(3)));
        check_3pc_inequality(&map, CtxInfo::single(10), 40, 1, 71, 1e-9);
    }

    #[test]
    fn prop_3pc_inequality_crandk() {
        let map = V4::new(Box::new(CRandK::new(3)), Box::new(CRandK::new(3)));
        check_3pc_inequality(&map, CtxInfo::single(8), 15, 4_000, 72, 0.08);
    }
}
