//! Baselines: exact gradient descent and *naive* DCGD (Eq. 2 with the
//! static mechanism `M_i^t ≡ C`, Eq. 3).
//!
//! GD is the A = 1, B = 0 corner of the 3PC framework (identity map).
//! Naive DCGD is **not** a 3PC compressor — its error `‖C(x) − x‖²`
//! does not shrink along the path, which is precisely the divergence
//! problem §2.1 describes and EF21/3PC fix; we keep it as the cautionary
//! baseline (`params()` returns `None`, so no theoretical stepsize
//! exists and the harness must be given one explicitly).

use super::{recycle_update, MechParams, ReplaceWire, ThreePointMap, Update};
use crate::compressors::{CVec, Contractive, Ctx, CtxInfo};

/// Exact gradient descent: `g_i^{t+1} = ∇f_i(x^{t+1})`, dense wire cost.
pub struct Gd;

impl ThreePointMap for Gd {
    fn name(&self) -> String {
        "GD".into()
    }

    fn spec(&self) -> String {
        "gd".into()
    }

    fn apply_into(&self, _h: &[f32], _y: &[f32], x: &[f32], ctx: &mut Ctx<'_>, out: &mut Update) {
        recycle_update(ctx, out);
        let g = ctx.take_f32_copy(x);
        *out = Update::Replace { g, bits: 32 * x.len() as u64, wire: ReplaceWire::Dense };
    }

    fn params(&self, _info: &CtxInfo) -> Option<MechParams> {
        Some(MechParams { a: 1.0, b: 0.0 })
    }
}

/// Naive DCGD: `g_i^{t+1} = C(∇f_i(x^{t+1}))` — static compression.
pub struct NaiveDcgd {
    c: Box<dyn Contractive>,
}

impl NaiveDcgd {
    pub fn new(c: Box<dyn Contractive>) -> NaiveDcgd {
        NaiveDcgd { c }
    }
}

impl ThreePointMap for NaiveDcgd {
    fn name(&self) -> String {
        format!("DCGD({})", self.c.name())
    }

    fn spec(&self) -> String {
        format!("dcgd:{}", self.c.spec())
    }

    fn apply_into(&self, _h: &[f32], _y: &[f32], x: &[f32], ctx: &mut Ctx<'_>, out: &mut Update) {
        recycle_update(ctx, out);
        let mut msg = CVec::Zero { dim: 0 };
        self.c.compress_into(x, ctx, &mut msg);
        let bits = msg.wire_bits();
        let mut g = ctx.take_f32_zeroed(x.len());
        msg.add_into_sh(ctx.shards(), &mut g);
        let mut parts = ctx.take_parts();
        parts.push(msg);
        *out = Update::Replace { g, bits, wire: ReplaceWire::Fresh(parts) };
    }

    fn params(&self, _info: &CtxInfo) -> Option<MechParams> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::TopK;
    use crate::mechanisms::proptests::check_3pc_inequality;
    use crate::util::rng::Pcg64;

    #[test]
    fn gd_is_exact() {
        let mut rng = Pcg64::seed(0);
        let info = CtxInfo::single(3);
        let u = Gd.apply(&[0.0; 3], &[0.0; 3], &[1.0, 2.0, 3.0], &mut Ctx::new(info, &mut rng, 0));
        match u {
            Update::Replace { g, bits, .. } => {
                assert_eq!(g, vec![1.0, 2.0, 3.0]);
                assert_eq!(bits, 96);
            }
            other => panic!("{other:?}"),
        }
        check_3pc_inequality(&Gd, CtxInfo::single(6), 30, 1, 1, 1e-12);
    }

    #[test]
    fn dcgd_has_no_certificate() {
        let d = NaiveDcgd::new(Box::new(TopK::new(1)));
        assert!(d.params(&CtxInfo::single(4)).is_none());
    }

    #[test]
    fn dcgd_compresses_the_raw_gradient() {
        let d = NaiveDcgd::new(Box::new(TopK::new(1)));
        let mut rng = Pcg64::seed(0);
        let info = CtxInfo::single(3);
        // Even when h already equals x, DCGD still throws information away
        // — the pathology that 3PC repairs.
        let x = [3.0f32, -1.0, 0.5];
        let u = d.apply(&x, &x, &x, &mut Ctx::new(info, &mut rng, 0));
        match u {
            Update::Replace { g, .. } => assert_eq!(g, vec![3.0, 0.0, 0.0]),
            other => panic!("{other:?}"),
        }
    }
}
