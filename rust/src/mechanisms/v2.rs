//! 3PCv2 (Algorithm 6) — unbiased estimator of the gradient difference
//! plus a contractive correction:
//!
//! `C_{h,y}(x) = b + C(x − b)` where `b = h + Q(x − y)`      (51)
//!
//! Lemma C.14: A = α, B = (1−α)ω.
//!
//! Two compressed messages cross the wire per round: `Q(x−y)` and
//! `C(x−b)` — both sparse for the sparsifier instantiations of the
//! experiments (Figures 1/5, 8–13); the bit accountant bills both.

use super::{recycle_update, MechParams, ReplaceWire, ThreePointMap, Update};
use crate::compressors::{CVec, Contractive, Ctx, CtxInfo, Unbiased};

pub struct V2 {
    q: Box<dyn Unbiased>,
    c: Box<dyn Contractive>,
}

impl V2 {
    pub fn new(q: Box<dyn Unbiased>, c: Box<dyn Contractive>) -> V2 {
        V2 { q, c }
    }
}

impl ThreePointMap for V2 {
    fn name(&self) -> String {
        format!("3PCv2({},{})", self.q.name(), self.c.name())
    }

    fn spec(&self) -> String {
        format!("v2:{}:{}", self.q.spec(), self.c.spec())
    }

    fn apply_into(&self, h: &[f32], y: &[f32], x: &[f32], ctx: &mut Ctx<'_>, out: &mut Update) {
        recycle_update(ctx, out);
        let sh = ctx.shards();
        let d = x.len();
        // b = h + Q(x − y); the diff buffer is then rebuilt in place
        // into b (one pooled buffer serves both roles).
        let mut diff = ctx.take_f32_zeroed(d);
        crate::kernels::diff(sh, x, y, &mut diff);
        let mut qmsg = CVec::Zero { dim: 0 };
        self.q.compress_into(&diff, ctx, &mut qmsg);
        let mut b = diff;
        crate::kernels::copy(sh, h, &mut b);
        qmsg.add_into_sh(sh, &mut b);
        // g = b + C(x − b)
        let mut residual = ctx.take_f32_zeroed(d);
        crate::kernels::diff(sh, x, &b, &mut residual);
        let mut cmsg = CVec::Zero { dim: 0 };
        self.c.compress_into(&residual, ctx, &mut cmsg);
        ctx.put_f32(residual);
        let mut g = b;
        cmsg.add_into_sh(sh, &mut g);
        let bits = qmsg.wire_bits() + cmsg.wire_bits();
        // Both compressed messages ARE the wire content: the server
        // rebuilds g = h + Q(x−y) + C(x−b) from its mirror of h.
        let mut parts = ctx.take_parts();
        parts.push(qmsg);
        parts.push(cmsg);
        *out = Update::Replace { g, bits, wire: ReplaceWire::FromPrev(parts) };
    }

    fn params(&self, info: &CtxInfo) -> Option<MechParams> {
        let alpha = self.c.alpha(info);
        let omega = self.q.omega(info);
        Some(MechParams { a: alpha, b: (1.0 - alpha) * omega })
    }

    fn uses_shared_randomness(&self) -> bool {
        true // when Q = Perm-K
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::{RandK, TopK};
    use crate::mechanisms::proptests::check_3pc_inequality;
    use crate::util::rng::Pcg64;

    #[test]
    fn table1_constants() {
        let info = CtxInfo::single(16);
        // α = 4/16 = 0.25, ω = 16/8 − 1 = 1 → A = 0.25, B = 0.75.
        let v2 = V2::new(Box::new(RandK::new(8)), Box::new(TopK::new(4)));
        let p = v2.params(&info).unwrap();
        assert!((p.a - 0.25).abs() < 1e-12);
        assert!((p.b - 0.75).abs() < 1e-12);
    }

    #[test]
    fn identity_q_recovers_perfect_tracking() {
        // With Q = identity (ω = 0), b = h + (x − y); if additionally
        // h = y then b = x and g = x exactly, whatever C is.
        use crate::compressors::identity::IdentityUnbiased;
        let v2 = V2::new(Box::new(IdentityUnbiased), Box::new(TopK::new(1)));
        let mut rng = Pcg64::seed(0);
        let info = CtxInfo::single(3);
        let y = [1.0f32, 2.0, 3.0];
        let x = [4.0f32, 5.0, 6.0];
        let u = v2.apply(&y, &y, &x, &mut Ctx::new(info, &mut rng, 0));
        match u {
            Update::Replace { g, .. } => assert_eq!(g, x.to_vec()),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn bills_both_messages() {
        let v2 = V2::new(Box::new(RandK::new(2)), Box::new(TopK::new(2)));
        let mut rng = Pcg64::seed(1);
        let info = CtxInfo::single(8);
        let u = v2.apply(&[0.0; 8], &[0.0; 8], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0], &mut Ctx::new(info, &mut rng, 0));
        // two sparse messages of 2 entries each: 2·(32+3)·2 = 140.
        assert_eq!(super::super::update_bits(&u), 2 * 2 * (32 + 3));
    }

    #[test]
    fn prop_3pc_inequality() {
        // Randomized (Rand-K inside): average over draws.
        let map = V2::new(Box::new(RandK::new(3)), Box::new(TopK::new(3)));
        check_3pc_inequality(&map, CtxInfo::single(9), 20, 4_000, 31, 0.08);
    }
}
