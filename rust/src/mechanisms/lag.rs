//! LAG (Algorithm 3; Chen et al. 2018, simplified per the paper) and the
//! paper's new CLAG (Algorithm 4).
//!
//! LAG: `C_{h,y}(x) = x` if `‖x − h‖² > ζ‖x − y‖²` else `h`   (36)
//!   — Lemma C.5: A = 1, B = ζ.
//!
//! CLAG: `C_{h,y}(x) = h + C(x − h)` if triggered, else `h`   (41)
//!   — Lemma C.8 (optimal s*): A = 1 − √(1−α),
//!     B = max{(1−α)/(1−√(1−α)), ζ}.
//!
//! The trigger fires when the stored estimate drifted from the fresh
//! gradient by more than ζ× the gradient's own movement; otherwise the
//! worker stays silent (zero payload bits — the essence of lazy
//! aggregation).

use super::{ef21::Ef21, recycle_update, MechParams, ReplaceWire, ThreePointMap, Update};
use crate::compressors::{CVec, Contractive, Ctx, CtxInfo};
use crate::kernels::{self, Shards};

/// The shared trigger predicate `‖x − h‖² > ζ‖x − y‖²`. The two
/// distance scans run on the chunked kernels, so a sharded evaluation
/// reaches the same fire/skip decision bit-for-bit as a serial one.
#[inline]
pub fn lag_trigger(sh: Shards<'_>, h: &[f32], y: &[f32], x: &[f32], zeta: f64) -> bool {
    kernels::dist_sq(sh, x, h) > zeta * kernels::dist_sq(sh, x, y)
}

pub struct Lag {
    pub zeta: f64,
}

impl Lag {
    pub fn new(zeta: f64) -> Lag {
        assert!(zeta >= 0.0, "ζ must be non-negative");
        Lag { zeta }
    }
}

impl ThreePointMap for Lag {
    fn name(&self) -> String {
        format!("LAG(zeta={})", self.zeta)
    }

    fn spec(&self) -> String {
        format!("lag:{}", self.zeta)
    }

    fn apply_into(&self, h: &[f32], y: &[f32], x: &[f32], ctx: &mut Ctx<'_>, out: &mut Update) {
        recycle_update(ctx, out);
        if lag_trigger(ctx.shards(), h, y, x, self.zeta) {
            let g = ctx.take_f32_copy(x);
            *out = Update::Replace { g, bits: 32 * x.len() as u64, wire: ReplaceWire::Dense };
        }
        // Otherwise the slot stays `Keep` — the skip path touches no
        // heap at all (the essence of lazy aggregation, now literally).
    }

    fn params(&self, _info: &CtxInfo) -> Option<MechParams> {
        Some(MechParams { a: 1.0, b: self.zeta })
    }
}

pub struct Clag {
    c: Box<dyn Contractive>,
    pub zeta: f64,
}

impl Clag {
    pub fn new(c: Box<dyn Contractive>, zeta: f64) -> Clag {
        assert!(zeta >= 0.0, "ζ must be non-negative");
        Clag { c, zeta }
    }
}

impl ThreePointMap for Clag {
    fn name(&self) -> String {
        format!("CLAG({},zeta={})", self.c.name(), self.zeta)
    }

    fn spec(&self) -> String {
        format!("clag:{}:{}", self.c.spec(), self.zeta)
    }

    fn apply_into(&self, h: &[f32], y: &[f32], x: &[f32], ctx: &mut Ctx<'_>, out: &mut Update) {
        recycle_update(ctx, out);
        if !lag_trigger(ctx.shards(), h, y, x, self.zeta) {
            return; // slot stays `Keep`
        }
        let mut residual = ctx.take_f32_zeroed(x.len());
        crate::kernels::diff(ctx.shards(), x, h, &mut residual);
        let mut inc = CVec::Zero { dim: 0 };
        self.c.compress_into(&residual, ctx, &mut inc);
        ctx.put_f32(residual);
        let bits = inc.wire_bits();
        *out = Update::Increment { inc, bits };
    }

    fn params(&self, info: &CtxInfo) -> Option<MechParams> {
        let alpha = self.c.alpha(info);
        let ef = Ef21::params_for_alpha(alpha);
        Some(MechParams { a: ef.a, b: ef.b.max(self.zeta) })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::TopK;
    use crate::mechanisms::proptests::check_3pc_inequality;
    use crate::mechanisms::{apply_update, update_bits};
    use crate::util::rng::Pcg64;

    fn ctx(rng: &mut Pcg64) -> Ctx<'_> {
        Ctx::new(CtxInfo::single(4), rng, 0)
    }

    #[test]
    fn lag_fires_and_skips() {
        let lag = Lag::new(1.0);
        let mut rng = Pcg64::seed(0);
        // h far from x, y close to x → fire.
        let h = [0.0f32; 4];
        let y = [1.0f32, 1.0, 1.0, 1.1];
        let x = [1.0f32; 4];
        let u = lag.apply(&h, &y, &x, &mut ctx(&mut rng));
        assert!(matches!(&u, Update::Replace { g, bits, .. } if g == &x.to_vec() && *bits == 128));
        // h == x → never fires (0 > ζ·anything is false).
        let u = lag.apply(&x, &y, &x, &mut ctx(&mut rng));
        assert!(matches!(u, Update::Keep));
        assert_eq!(update_bits(&u), 0);
    }

    #[test]
    fn lag_zeta_zero_always_fires_unless_exact() {
        // ζ = 0: fires whenever ‖x−h‖² > 0 → behaves like GD.
        let lag = Lag::new(0.0);
        let mut rng = Pcg64::seed(0);
        let u = lag.apply(&[0.0; 4], &[0.5; 4], &[1.0; 4], &mut ctx(&mut rng));
        assert!(matches!(u, Update::Replace { .. }));
    }

    #[test]
    fn clag_reduces_to_lag_with_identity() {
        use crate::compressors::Identity;
        let clag = Clag::new(Box::new(Identity), 2.0);
        let lag = Lag::new(2.0);
        let mut rng = Pcg64::seed(7);
        let h = [0.0f32, 1.0, -1.0, 2.0];
        let y = [0.5f32, 0.5, 0.5, 0.5];
        let x = [1.0f32, -1.0, 0.0, 3.0];
        let uc = clag.apply(&h, &y, &x, &mut ctx(&mut rng));
        let ul = lag.apply(&h, &y, &x, &mut ctx(&mut rng));
        assert_eq!(apply_update(&h, &uc), apply_update(&h, &ul));
    }

    #[test]
    fn clag_reduces_to_ef21_with_zeta_zero() {
        use crate::mechanisms::Ef21;
        let clag = Clag::new(Box::new(TopK::new(2)), 0.0);
        let ef = Ef21::new(Box::new(TopK::new(2)));
        let mut rng = Pcg64::seed(9);
        let h = [0.0f32, 1.0, -1.0, 2.0];
        let y = [0.5f32, 0.5, 0.5, 0.5];
        let x = [1.0f32, -1.0, 0.0, 3.0];
        let uc = clag.apply(&h, &y, &x, &mut ctx(&mut rng));
        let ue = ef.apply(&h, &y, &x, &mut ctx(&mut rng));
        assert_eq!(apply_update(&h, &uc), apply_update(&h, &ue));
    }

    #[test]
    fn table1_constants() {
        let info = CtxInfo::single(16);
        let lag = Lag::new(5.0);
        assert_eq!(lag.params(&info).unwrap(), MechParams { a: 1.0, b: 5.0 });
        // CLAG with α = 3/4: EF21 part gives A = 1/2, B = 1/2; ζ = 3
        // dominates the max.
        let clag = Clag::new(Box::new(TopK::new(12)), 3.0);
        let p = clag.params(&info).unwrap();
        assert!((p.a - 0.5).abs() < 1e-12);
        assert!((p.b - 3.0).abs() < 1e-12);
    }

    #[test]
    fn prop_3pc_inequality_lag() {
        check_3pc_inequality(&Lag::new(1.5), CtxInfo::single(8), 60, 1, 11, 1e-9);
    }

    #[test]
    fn prop_3pc_inequality_clag() {
        let map = Clag::new(Box::new(TopK::new(3)), 2.0);
        check_3pc_inequality(&map, CtxInfo::single(10), 60, 1, 13, 1e-9);
    }
}
