//! 3PCv1 (Algorithm 5) — the "idealized EF21" with a gradient shift:
//!
//! `C_{h,y}(x) = y + C(x − y)`                              (46)
//!
//! Lemma C.11: A = 1, B = 1 − α.
//!
//! Impractical on the wire (the server does not know `y = ∇f_i(x^t)`, so
//! the worker must transmit it densely each round — we bill exactly that:
//! `32·d` bits for the shift plus the compressed difference), but it
//! bounds what EF21 could achieve with a perfect memory of the previous
//! gradient. Reproduced in Figure 16.

use super::{recycle_update, MechParams, ReplaceWire, ThreePointMap, Update};
use crate::compressors::{CVec, Contractive, Ctx, CtxInfo};

pub struct V1 {
    c: Box<dyn Contractive>,
}

impl V1 {
    pub fn new(c: Box<dyn Contractive>) -> V1 {
        V1 { c }
    }
}

impl ThreePointMap for V1 {
    fn name(&self) -> String {
        format!("3PCv1({})", self.c.name())
    }

    fn spec(&self) -> String {
        format!("v1:{}", self.c.spec())
    }

    fn apply_into(&self, _h: &[f32], y: &[f32], x: &[f32], ctx: &mut Ctx<'_>, out: &mut Update) {
        recycle_update(ctx, out);
        let sh = ctx.shards();
        let d = x.len();
        let mut diff = ctx.take_f32_zeroed(d);
        crate::kernels::diff(sh, x, y, &mut diff);
        let mut comp = CVec::Zero { dim: 0 };
        self.c.compress_into(&diff, ctx, &mut comp);
        ctx.put_f32(diff);
        let mut g = ctx.take_f32_copy(y);
        comp.add_into_sh(sh, &mut g);
        // Wire cost: dense shift y (the server has no copy) + the
        // compressed difference — the paper's d + K floats per node.
        let bits = 32 * d as u64 + comp.wire_bits();
        let shift = ctx.take_f32_copy(y);
        let mut parts = ctx.take_parts();
        parts.push(CVec::Dense(shift));
        parts.push(comp);
        *out = Update::Replace { g, bits, wire: ReplaceWire::Fresh(parts) };
    }

    fn params(&self, info: &CtxInfo) -> Option<MechParams> {
        Some(MechParams { a: 1.0, b: 1.0 - self.c.alpha(info) })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::TopK;
    use crate::mechanisms::proptests::check_3pc_inequality;
    use crate::util::rng::Pcg64;

    #[test]
    fn ignores_h_entirely() {
        let v1 = V1::new(Box::new(TopK::new(1)));
        let mut rng = Pcg64::seed(0);
        let y = [1.0f32, 2.0];
        let x = [1.0f32, 5.0];
        let info = CtxInfo::single(2);
        let u1 = v1.apply(&[0.0; 2], &y, &x, &mut Ctx::new(info, &mut rng, 0));
        let u2 = v1.apply(&[9.0; 2], &y, &x, &mut Ctx::new(info, &mut rng, 0));
        match (&u1, &u2) {
            (Update::Replace { g: g1, .. }, Update::Replace { g: g2, .. }) => {
                assert_eq!(g1, g2);
                assert_eq!(g1, &vec![1.0, 5.0]); // y + Top1(x−y) fills coord 1
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn bills_the_dense_shift() {
        let v1 = V1::new(Box::new(TopK::new(1)));
        let mut rng = Pcg64::seed(0);
        let info = CtxInfo::single(4);
        let u = v1.apply(&[0.0; 4], &[0.0; 4], &[1.0, 0.0, 0.0, 0.0], &mut Ctx::new(info, &mut rng, 0));
        // 32·4 dense + (32+2) sparse single entry.
        assert_eq!(super::super::update_bits(&u), 128 + 34);
    }

    #[test]
    fn table1_constants() {
        let info = CtxInfo::single(16);
        let p = V1::new(Box::new(TopK::new(4))).params(&info).unwrap();
        assert_eq!(p, MechParams { a: 1.0, b: 0.75 });
    }

    #[test]
    fn prop_3pc_inequality() {
        let map = V1::new(Box::new(TopK::new(3)));
        check_3pc_inequality(&map, CtxInfo::single(9), 50, 1, 21, 1e-9);
    }
}
