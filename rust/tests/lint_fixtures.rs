//! Fixture tests for `threepc lint` (the `analysis` module): every rule
//! must fire on a minimal guilty snippet *at the right line*, stay
//! quiet on the innocent near-miss, honor waivers, and reject malformed
//! waivers. The final test runs the real linter over this checkout —
//! the same gate CI applies — so a deleted waiver or a fresh violation
//! fails the suite even before the CI lint step runs.

use threepc::analysis::{lint_sources, lint_tree, Diagnostic, LintReport};

/// Lint one in-memory file (no R4 corpus).
fn lint_one(path: &str, text: &str) -> LintReport {
    lint_sources(&[(path.to_string(), text.to_string())], None)
}

/// The (line, rule) pairs of a report, for order-insensitive asserts.
fn hits(r: &LintReport) -> Vec<(usize, &'static str)> {
    r.diagnostics.iter().map(|d| (d.line, d.rule)).collect()
}

fn assert_clean(r: &LintReport) {
    assert!(
        r.is_clean(),
        "expected clean, got: {:?}",
        r.diagnostics.iter().map(Diagnostic::render).collect::<Vec<_>>()
    );
}

// ---------------------------------------------------------------- R1

#[test]
fn determinism_fires_on_trace_files_at_line() {
    let src = "use std::collections::HashMap;\n\
               fn f() {\n\
               let m: HashMap<u32, u32> = HashMap::new();\n\
               let t = Instant::now();\n\
               let s = SystemTime::now();\n\
               }\n";
    let r = lint_one("rust/src/mechanisms/fixture.rs", src);
    let h = hits(&r);
    // Two HashMap mentions on line 3, one on line 1.
    assert_eq!(h.iter().filter(|&&(l, ru)| l == 1 && ru == "determinism").count(), 1);
    assert_eq!(h.iter().filter(|&&(l, ru)| l == 3 && ru == "determinism").count(), 2);
    assert!(h.contains(&(4, "determinism")), "Instant::now must fire: {h:?}");
    assert!(h.contains(&(5, "determinism")), "SystemTime must fire: {h:?}");
}

#[test]
fn determinism_ignores_non_trace_files_and_identifier_prefixes() {
    let src = "use std::collections::HashMap;\nfn f(m: &HashMap<u32, u32>) {}\n";
    assert_clean(&lint_one("rust/src/util/fixture.rs", src));
    // `MyHashMapLike` is not a word-boundary hit.
    let src = "struct MyHashMapLike;\nfn g(_: MyHashMapLike) {}\n";
    assert_clean(&lint_one("rust/src/mechanisms/fixture.rs", src));
}

// ---------------------------------------------------------------- R2

#[test]
fn float_fold_fires_outside_kernels_at_line() {
    let src = "fn f(xs: &[f64]) -> f64 {\n\
               let a = xs.iter().sum::<f64>();\n\
               let b = xs.iter().fold(0.0f64, |m, &v| m + v);\n\
               let mut acc = 0.0;\n\
               for &v in xs {\n\
               acc += v;\n\
               }\n\
               a + b + acc\n\
               }\n";
    let r = lint_one("rust/src/experiments/fixture.rs", src);
    let h = hits(&r);
    assert!(h.contains(&(2, "float-fold")), "typed float sum must fire: {h:?}");
    assert!(h.contains(&(3, "float-fold")), "float fold must fire: {h:?}");
    assert!(h.contains(&(6, "float-fold")), "loop accumulation must fire: {h:?}");
}

#[test]
fn float_fold_exempts_kernels_and_integer_folds() {
    let src = "fn f(xs: &[f64]) -> f64 {\nxs.iter().sum::<f64>()\n}\n";
    assert_clean(&lint_one("rust/src/kernels/fixture.rs", src));
    // An explicitly integer-typed sum is fine anywhere.
    let src = "fn f(xs: &[u64]) -> u64 {\nxs.iter().sum::<u64>()\n}\n";
    assert_clean(&lint_one("rust/src/experiments/fixture.rs", src));
    // `+=` outside any `for` loop body does not fire.
    let src = "fn f(mut a: f64, b: f64) -> f64 {\na += b;\na\n}\n";
    assert_clean(&lint_one("rust/src/experiments/fixture.rs", src));
}

// ---------------------------------------------------------------- R3

#[test]
fn wire_panic_and_cast_fire_in_wire_files_at_line() {
    let src = "fn f(buf: &[u8], v: Vec<u8>) -> u32 {\n\
               let a = buf.first().unwrap();\n\
               let b: [u8; 2] = buf[0..2].try_into().expect(\"two\");\n\
               assert!(buf.len() > 4);\n\
               let n = v.len() as u32;\n\
               let big = u64::from_le_bytes([0; 8]) as usize;\n\
               n + *a as u32 + b[0] as u32 + big as u32\n\
               }\n";
    let r = lint_one("rust/src/coordinator/service/fixture.rs", src);
    let h = hits(&r);
    assert!(h.contains(&(2, "wire-panic")), "unwrap must fire: {h:?}");
    assert!(h.contains(&(3, "wire-panic")), "expect must fire: {h:?}");
    assert!(h.contains(&(4, "wire-panic")), "assert! must fire: {h:?}");
    assert!(h.contains(&(5, "wire-cast")), "length cast must fire: {h:?}");
    assert!(h.contains(&(6, "wire-cast")), "u64-as-usize must fire: {h:?}");
}

#[test]
fn wire_rules_exempt_non_wire_files_and_debug_assert() {
    let src = "fn f(v: &[u8]) -> u32 {\nv.len() as u32\n}\n";
    assert_clean(&lint_one("rust/src/experiments/fixture.rs", src));
    let src = "fn f(buf: &[u8]) {\ndebug_assert!(buf.len() > 4);\n}\n";
    assert_clean(&lint_one("rust/src/coordinator/service/fixture.rs", src));
    // Poison recovery is the sanctioned lock idiom — must NOT fire.
    let src = "fn f(m: &std::sync::Mutex<u32>) -> u32 {\n\
               *m.lock().unwrap_or_else(|p| p.into_inner())\n\
               }\n";
    assert_clean(&lint_one("rust/src/coordinator/service/fixture.rs", src));
}

#[test]
fn test_modules_are_skipped() {
    let src = "fn f() {}\n\
               #[cfg(test)]\n\
               mod tests {\n\
               #[test]\n\
               fn t() {\n\
               let v: Vec<u8> = vec![];\n\
               let _ = v.first().unwrap();\n\
               }\n\
               }\n";
    assert_clean(&lint_one("rust/src/coordinator/service/fixture.rs", src));
}

// ---------------------------------------------------------------- R4

#[test]
fn wire_registry_catches_duplicate_tags() {
    let src = "pub const TAG_A: u8 = 0x01;\n\
               pub const TAG_B: u8 = 0x01;\n\
               pub const TAG_A2: u8 = 0x02;\n";
    // Duplicate *name* across files.
    let src2 = "pub const TAG_A2: u8 = 0x03;\n";
    let r = lint_sources(
        &[
            ("rust/src/coordinator/service/a.rs".to_string(), src.to_string()),
            ("rust/src/coordinator/service/b.rs".to_string(), src2.to_string()),
        ],
        None,
    );
    let dup_value = r
        .diagnostics
        .iter()
        .any(|d| d.rule == "wire-registry" && d.line == 2 && d.message.contains("0x01"));
    assert!(dup_value, "duplicate tag value must fire: {:?}", hits(&r));
    let dup_name = r
        .diagnostics
        .iter()
        .any(|d| d.rule == "wire-registry" && d.message.contains("TAG_A2"));
    assert!(dup_name, "duplicate tag name must fire: {:?}", hits(&r));
}

#[test]
fn wire_registry_requires_decode_partners() {
    let src = "pub fn encode_widget(v: u8) -> Vec<u8> { vec![v] }\n\
               pub fn encode_gadget(v: u8) -> Vec<u8> { vec![v] }\n\
               pub fn decode_gadget(_: &[u8]) {}\n";
    let r = lint_one("rust/src/coordinator/service/fixture.rs", src);
    let h = hits(&r);
    assert!(
        h.contains(&(1, "wire-registry")),
        "unpaired encoder must fire: {h:?}"
    );
    assert!(!h.contains(&(2, "wire-registry")), "paired encoder must not fire: {h:?}");
    // Buffer-reusing suffix forms pair with the base decoder.
    let src = "pub fn encode_widget_into(v: u8, out: &mut Vec<u8>) { out.push(v) }\n\
               fn decode_widget(_: &[u8]) {}\n";
    assert_clean(&lint_one("rust/src/coordinator/service/fixture.rs", src));
}

#[test]
fn wire_registry_requires_fuzz_corpus_coverage() {
    let src = "pub const TAG_A: u8 = 0x31;\npub const TAG_B: u8 = 0x32;\n";
    // Corpus mentions TAG_A only.
    let r = lint_sources(
        &[("rust/src/coordinator/service/fixture.rs".to_string(), src.to_string())],
        Some("fn fuzz() { let _ = TAG_A; }"),
    );
    let h = hits(&r);
    assert!(!h.contains(&(1, "wire-registry")), "covered tag must not fire: {h:?}");
    assert!(h.contains(&(2, "wire-registry")), "uncovered tag must fire: {h:?}");
    // No corpus supplied → the coverage check is skipped entirely.
    assert_clean(&lint_one("rust/src/coordinator/service/fixture.rs", src));
}

// ---------------------------------------------------------------- R5

#[test]
fn struct_lit_fires_outside_home_module_at_line() {
    let src = "fn f() {\n\
               let r = RoundRecord { t: 0 };\n\
               let c = Checkpoint { t: 1 };\n\
               }\n";
    let r = lint_one("rust/src/experiments/fixture.rs", src);
    let h = hits(&r);
    assert!(h.contains(&(2, "struct-lit")), "RoundRecord literal must fire: {h:?}");
    assert!(h.contains(&(3, "struct-lit")), "Checkpoint literal must fire: {h:?}");
}

#[test]
fn struct_lit_exempts_home_modules_and_type_positions() {
    let src = "fn f() {\nlet r = RoundRecord { t: 0 };\n}\n";
    assert_clean(&lint_one("rust/src/coordinator/metrics.rs", src));
    let src = "pub fn run() -> TrainResult {\ntodo()\n}\n\
               impl TrainResult {}\n\
               struct TrainResult {}\n\
               fn g(r: &TrainResult {}) {}\n";
    assert_clean(&lint_one("rust/src/experiments/fixture.rs", src));
}

// ------------------------------------------------------------ waivers

#[test]
fn waivers_suppress_trailing_and_preceding_forms() {
    let src = "fn f(buf: &[u8]) -> u8 {\n\
               *buf.first().unwrap() // lint:allow(wire-panic): fixture — caller checks len\n\
               }\n";
    let r = lint_one("rust/src/coordinator/service/fixture.rs", src);
    assert_clean(&r);
    assert_eq!(r.waivers, 1);

    let src = "fn f(buf: &[u8]) -> u8 {\n\
               // lint:allow(wire-panic): fixture — caller checks len\n\
               *buf.first().unwrap()\n\
               }\n";
    let r = lint_one("rust/src/coordinator/service/fixture.rs", src);
    assert_clean(&r);
    assert_eq!(r.waivers, 1);
}

#[test]
fn waiver_covers_only_its_own_rule() {
    // A float-fold waiver does not excuse a wire-panic on the same line.
    let src = "fn f(buf: &[u8]) -> u8 {\n\
               *buf.first().unwrap() // lint:allow(float-fold): wrong rule\n\
               }\n";
    let r = lint_one("rust/src/coordinator/service/fixture.rs", src);
    assert_eq!(hits(&r), vec![(2, "wire-panic")]);
}

#[test]
fn waiver_without_reason_is_an_error() {
    let src = "fn f(buf: &[u8]) -> u8 {\n\
               *buf.first().unwrap() // lint:allow(wire-panic)\n\
               }\n";
    let r = lint_one("rust/src/coordinator/service/fixture.rs", src);
    let h = hits(&r);
    assert!(h.contains(&(2, "waiver")), "reasonless waiver must be flagged: {h:?}");
    assert!(h.contains(&(2, "wire-panic")), "a malformed waiver must not suppress: {h:?}");
    // Same for a colon with only whitespace after it.
    let src = "fn f() {}\n// lint:allow(wire-panic):   \n";
    let r = lint_one("rust/src/coordinator/service/fixture.rs", src);
    assert!(hits(&r).contains(&(2, "waiver")));
}

#[test]
fn waiver_with_unknown_rule_is_an_error() {
    let src = "fn f() {}\n// lint:allow(no-such-rule): reason text\n";
    let r = lint_one("rust/src/coordinator/service/fixture.rs", src);
    assert_eq!(hits(&r), vec![(2, "waiver")]);
    assert!(r.diagnostics[0].message.contains("no-such-rule"));
}

#[test]
fn prose_mentions_of_the_grammar_are_not_waivers() {
    // Doc comments describing `lint:allow(<rule>): <reason>` must parse
    // as prose, not as (malformed) waivers.
    let src = "//! Use `lint:allow(<rule>): <reason>` to waive a finding.\n\
               /// See lint:allow(<rule>) for details.\n\
               fn f() {}\n";
    let r = lint_one("rust/src/coordinator/service/fixture.rs", src);
    assert_clean(&r);
    assert_eq!(r.waivers, 0);
}

#[test]
fn tokens_inside_strings_and_comments_do_not_fire() {
    let src = "fn f() -> &'static str {\n\
               // HashMap in a comment is fine, as is .unwrap() here\n\
               \"HashMap::new().unwrap() as u32\"\n\
               }\n";
    assert_clean(&lint_one("rust/src/coordinator/protocol.rs", src));
}

// --------------------------------------------------------- the gate

/// The real gate: this checkout lints clean. Any new violation — or any
/// deleted waiver — fails this test (and the CI lint step).
#[test]
fn tree_lints_clean() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = lint_tree(root).expect("walking rust/src");
    assert!(report.files > 50, "walked only {} files — wrong root?", report.files);
    assert!(report.waivers > 30, "only {} waivers parsed — wrong root?", report.waivers);
    assert!(
        report.is_clean(),
        "tree must lint clean:\n{}",
        report
            .diagnostics
            .iter()
            .map(Diagnostic::render)
            .collect::<Vec<_>>()
            .join("\n")
    );
    // The JSON rendering of a clean run is stable and parseable-ish.
    let json = report.to_json();
    assert!(json.starts_with("{\"diagnostics\":[]"), "unexpected json: {json}");
}
