//! Integration: the PJRT/HLO execution path vs the native Rust oracle.
//!
//! Requires `make artifacts` (the Makefile's `test` target guarantees it).
//! These tests prove the three layers compose: JAX/Pallas (L1/L2) →
//! HLO text → PJRT compile/execute from the Rust coordinator (L3),
//! with numerics pinned to the independent native implementations.

use std::sync::Arc;

use threepc::coordinator::{InitPolicy, TrainConfig, TrainSession};
use threepc::data;
use threepc::mechanisms::parse_mechanism;
use threepc::problems::{Autoencoder, Distributed, LocalProblem, LogReg, QuadLocal};
use threepc::runtime::{DeviceService, HloAutoencoder, HloLogReg, HloQuad, Manifest};
use threepc::util::rng::Pcg64;

fn manifest() -> Manifest {
    Manifest::load(threepc::runtime::default_artifacts_dir())
        .expect("run `make artifacts` before `cargo test`")
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for i in 0..a.len() {
        let scale = 1.0 + a[i].abs().max(b[i].abs());
        assert!(
            (a[i] - b[i]).abs() / scale < tol,
            "{what}: coord {i}: native {} vs hlo {}",
            a[i],
            b[i]
        );
    }
}

#[test]
#[ignore = "needs `make artifacts` + the `pjrt` cargo feature (xla crate not vendored offline)"]
fn logreg_hlo_matches_native() {
    let manifest = manifest();
    let dev = DeviceService::start().expect("PJRT CPU client");
    let m = manifest.prop("logreg_ijcnn1", "m").unwrap();
    let d = manifest.prop("logreg_ijcnn1", "d").unwrap();

    let mut rng = Pcg64::seed(11);
    let rows: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32).collect();
    let labels: Vec<f32> = (0..m).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();

    let native = LogReg::new(rows.clone(), labels.clone(), d, 0.1);
    let hlo = HloLogReg::new(dev.handle(), &manifest, "ijcnn1", "w0", rows, labels).unwrap();

    let x: Vec<f32> = (0..d).map(|_| rng.normal_ms(0.0, 0.5) as f32).collect();
    let mut gn = vec![0.0f32; d];
    let mut gh = vec![0.0f32; d];
    native.grad(&x, &mut gn);
    hlo.grad(&x, &mut gh);
    assert_close(&gn, &gh, 1e-4, "logreg grad");
    let (ln, lh) = (native.loss(&x), hlo.loss(&x));
    assert!((ln - lh).abs() / (1.0 + ln.abs()) < 1e-5, "loss {ln} vs {lh}");
}

#[test]
#[ignore = "needs `make artifacts` + the `pjrt` cargo feature (xla crate not vendored offline)"]
fn quad_hlo_matches_native() {
    let manifest = manifest();
    let dev = DeviceService::start().expect("PJRT CPU client");
    let d = manifest.prop("quad_grad", "d").unwrap();
    let mut rng = Pcg64::seed(13);
    let b: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
    let (nu, shift) = (1.7, 0.9);

    let native = QuadLocal::new(nu, shift, b.clone());
    let hlo = HloQuad::new(dev.handle(), &manifest, "w0", nu, shift, b).unwrap();

    let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
    let mut gn = vec![0.0f32; d];
    let mut gh = vec![0.0f32; d];
    native.grad(&x, &mut gn);
    hlo.grad(&x, &mut gh);
    assert_close(&gn, &gh, 1e-4, "quad grad");
    let (ln, lh) = (native.loss(&x), hlo.loss(&x));
    assert!((ln - lh).abs() / (1.0 + ln.abs()) < 1e-4, "loss {ln} vs {lh}");
}

#[test]
#[ignore = "needs `make artifacts` + the `pjrt` cargo feature (xla crate not vendored offline)"]
fn autoencoder_hlo_matches_native() {
    let manifest = manifest();
    let dev = DeviceService::start().expect("PJRT CPU client");
    let m = manifest.prop("ae_grad", "m").unwrap();
    let d_f = manifest.prop("ae_grad", "d_f").unwrap();
    let d_e = manifest.prop("ae_grad", "d_e").unwrap();

    let ds = data::synthetic_mnist(m, 17);
    assert_eq!(ds.d, d_f);
    let native = Autoencoder::new(ds.x.clone(), d_f, d_e);
    let hlo = HloAutoencoder::new(dev.handle(), &manifest, "w0", ds.x).unwrap();

    let mut rng = Pcg64::seed(19);
    let dim = 2 * d_f * d_e;
    let x: Vec<f32> = (0..dim).map(|_| rng.normal_ms(0.0, 0.05) as f32).collect();
    let mut gn = vec![0.0f32; dim];
    let mut gh = vec![0.0f32; dim];
    native.grad(&x, &mut gn);
    hlo.grad(&x, &mut gh);
    assert_close(&gn, &gh, 5e-3, "ae grad");
    let (ln, lh) = (native.loss(&x), hlo.loss(&x));
    assert!((ln - lh).abs() / (1.0 + ln.abs()) < 1e-4, "loss {ln} vs {lh}");
}

/// End-to-end: a short distributed EF21 training run entirely through the
/// HLO gradient path must track the native run round-for-round.
#[test]
#[ignore = "needs `make artifacts` + the `pjrt` cargo feature (xla crate not vendored offline)"]
fn training_through_hlo_matches_native_run() {
    let manifest = manifest();
    let dev = DeviceService::start().expect("PJRT CPU client");
    let d = manifest.prop("quad_grad", "d").unwrap();
    let n = 4;

    let suite = threepc::problems::quadratic::generate(n, d, 1e-2, 0.5, 23);
    let native = &suite.problem;

    let hlo_locals: Vec<Arc<dyn LocalProblem>> = suite
        .locals
        .iter()
        .enumerate()
        .map(|(i, q)| {
            Arc::new(
                HloQuad::new(dev.handle(), &manifest, &format!("w{i}"), q.nu, q.shift, q.b.clone())
                    .unwrap(),
            ) as Arc<dyn LocalProblem>
        })
        .collect();
    let hlo_problem = Distributed::new(hlo_locals, native.x0.clone());

    let cfg = TrainConfig {
        gamma: 0.05 / suite.l_minus,
        max_rounds: 25,
        threads: 2,
        seed: 5,
        init: InitPolicy::FullGradient,
        ..TrainConfig::default()
    };
    let map = parse_mechanism("ef21:top32").unwrap();
    let rn = TrainSession::builder(native).mechanism(map.clone()).config(cfg.clone()).run();
    let rh = TrainSession::builder(&hlo_problem).mechanism(map).config(cfg).run();

    assert_eq!(rn.rounds_run, rh.rounds_run);
    for (a, b) in rn.records.iter().zip(&rh.records) {
        let rel = (a.grad_norm_sq - b.grad_norm_sq).abs() / (1e-12 + a.grad_norm_sq);
        assert!(rel < 1e-3, "round {}: native {} vs hlo {}", a.t, a.grad_norm_sq, b.grad_norm_sq);
        assert_eq!(a.bits_up_cum, b.bits_up_cum, "bit accounting must be identical");
    }
}
