//! Fault-injection acceptance suite for the self-healing socket
//! transport (unix only — the recovery machinery rides on poll(2)):
//!
//! * **Quorum rounds** with scripted stragglers must be bit-identical
//!   across reruns, with the per-round `absent` sets pinned to the
//!   [`FaultPlan`], and a quorum session in which nobody is ever absent
//!   must reproduce the full-participation trace bit-for-bit.
//! * **Crash → reconnect → resync** in the default blocking mode must
//!   reproduce the uninterrupted reference round-for-round, bit-for-bit,
//!   with `transport_error: None`.
//! * **Absence-budget exhaustion** must surface as a
//!   `transport_error` naming the worker, with the partial trace
//!   retained.
#![cfg(unix)]

use std::sync::atomic::{AtomicUsize, Ordering};
use std::thread;
use std::time::Duration;

use threepc::coordinator::socket::quad_problem_spec;
use threepc::coordinator::{
    run_worker_agent, AgentConfig, FaultPlan, FaultScript, Socket, TrainConfig, TrainResult,
    TrainSession, TransportError,
};
use threepc::problems::quadratic;

const N: usize = 4;
const D: usize = 30;
const LAMBDA: f64 = 1e-2;
const NOISE: f64 = 0.5;
const QSEED: u64 = 21;

/// EF21 over Top-K: y-independent and randomness-free, so a resynced
/// worker reconstructs its mechanism state exactly from the leader's
/// `g_i` mirror — the bit-equality assertions below rely on that.
const SPEC: &str = "ef21:top3";

fn suite() -> quadratic::QuadSuite {
    quadratic::generate(N, D, LAMBDA, NOISE, QSEED)
}

fn problem_spec() -> String {
    quad_problem_spec(N, D, LAMBDA, NOISE, QSEED)
}

/// A generous `quorum_grace` so a healthy-but-scheduled-out loopback
/// worker is never demoted on timing — every demotion in this suite
/// comes from the [`FaultPlan`], keeping the traces deterministic.
fn cfg(rounds: usize, quorum: Option<usize>) -> TrainConfig {
    TrainConfig {
        gamma: 0.02,
        max_rounds: rounds,
        threads: 1,
        seed: 13,
        quorum,
        quorum_grace: Duration::from_secs(5),
        ..TrainConfig::default()
    }
}

fn bind_socket(addr: &str) -> Socket {
    Socket::bind(addr, &problem_spec())
        .expect("bind")
        .accept_timeout(Duration::from_secs(60))
        .io_timeout(Duration::from_secs(60))
}

/// A fresh, short, unique uds path (parallel tests must not collide).
fn uds_addr() -> String {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    let id = NEXT.fetch_add(1, Ordering::Relaxed);
    let path = std::env::temp_dir().join(format!("3pcf-{}-{}.sock", std::process::id(), id));
    format!("uds://{}", path.display())
}

/// Spawn one agent per config (index = spawn order, not worker id —
/// ids are assigned by accept order, which loopback keeps aligned
/// closely enough for these scripts to land on *some* worker
/// deterministically only when every agent carries the same script;
/// tests that pin a specific worker id do it through the leader-side
/// [`FaultPlan`] instead).
fn spawn_agents_with(
    addr: &str,
    cfgs: Vec<AgentConfig>,
) -> Vec<thread::JoinHandle<anyhow::Result<()>>> {
    cfgs.into_iter()
        .map(|c| {
            let a = addr.to_string();
            thread::spawn(move || run_worker_agent(&a, &c))
        })
        .collect()
}

fn join_agents(joins: Vec<thread::JoinHandle<anyhow::Result<()>>>) {
    for j in joins {
        j.join().expect("agent thread").expect("agent exits cleanly");
    }
}

fn run_session(sock: Socket, c: &TrainConfig, agent_cfgs: Vec<AgentConfig>) -> TrainResult {
    let s = suite();
    let listen = sock.local_addr().expect("bound address");
    let joins = spawn_agents_with(&listen, agent_cfgs);
    let r = TrainSession::builder(&s.problem)
        .mechanism_spec(SPEC)
        .unwrap()
        .config(c.clone())
        .transport(sock)
        .run();
    join_agents(joins);
    r
}

fn default_agents(n: usize) -> Vec<AgentConfig> {
    (0..n).map(|_| AgentConfig::default()).collect()
}

/// Bit-for-bit physics equality plus the billed-uplink ledger (the
/// resync path must bill recovered replies exactly like ordinary ones).
fn assert_trace_eq(a: &TrainResult, b: &TrainResult, tag: &str) {
    assert_eq!(a.rounds_run, b.rounds_run, "{tag}: rounds_run");
    assert_eq!(a.records.len(), b.records.len(), "{tag}: record count");
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(
            ra.grad_norm_sq.to_bits(),
            rb.grad_norm_sq.to_bits(),
            "{tag} round {}: grad_norm_sq {} vs {}",
            ra.t,
            ra.grad_norm_sq,
            rb.grad_norm_sq
        );
        assert_eq!(ra.g_err.to_bits(), rb.g_err.to_bits(), "{tag} round {}: g_err", ra.t);
        assert_eq!(ra.skipped_frac, rb.skipped_frac, "{tag} round {}: skipped_frac", ra.t);
        assert_eq!(ra.bits_up_cum, rb.bits_up_cum, "{tag} round {}: bits_up_cum", ra.t);
        assert_eq!(ra.bits_down_cum, rb.bits_down_cum, "{tag} round {}: bits_down_cum", ra.t);
        assert_eq!(ra.absent, rb.absent, "{tag} round {}: absent set", ra.t);
        assert_eq!(ra.mech_switch, rb.mech_switch, "{tag} round {}: mech_switch", ra.t);
        assert_eq!(ra.loss, rb.loss, "{tag} round {}: loss", ra.t);
    }
    for (i, (xa, xb)) in a.final_x.iter().zip(&b.final_x).enumerate() {
        assert_eq!(xa.to_bits(), xb.to_bits(), "{tag}: final_x[{i}]");
    }
}

fn absent_at(r: &TrainResult, t: usize) -> Vec<u32> {
    r.records
        .iter()
        .find(|rec| rec.t == t)
        .unwrap_or_else(|| panic!("no record for round {t}"))
        .absent
        .clone()
}

/// A quorum session with leader-scripted demotions is deterministic:
/// rerunning the identical plan reproduces the trace bit-for-bit, and
/// every `absent` set is exactly what the plan demanded — never a
/// timing artifact.
#[test]
fn scripted_quorum_stragglers_are_bit_reproducible() {
    let plan = || FaultPlan::new().demote(3, &[1]).demote(5, &[0]).demote(6, &[0]);
    let c = cfg(12, Some(3));
    let run = || {
        let sock = bind_socket(&uds_addr()).fault_plan(plan());
        run_session(sock, &c, default_agents(N))
    };
    let a = run();
    assert!(a.transport_error.is_none(), "{:?}", a.transport_error);
    // The absent sets are pinned by the plan, round for round.
    for rec in &a.records {
        let expect: Vec<u32> = match rec.t {
            3 => vec![1],
            5 | 6 => vec![0],
            _ => vec![],
        };
        assert_eq!(rec.absent, expect, "round {}: absent set", rec.t);
    }
    let b = run();
    assert_trace_eq(&a, &b, "scripted quorum rerun");
}

/// A quorum session in which every worker always answers inside the
/// grace window is indistinguishable — bit-for-bit — from the default
/// full-participation mode.
#[test]
fn quorum_with_full_participation_matches_blocking_mode() {
    let full = run_session(bind_socket(&uds_addr()), &cfg(12, None), default_agents(N));
    assert!(full.transport_error.is_none(), "{:?}", full.transport_error);
    let quorum = run_session(bind_socket(&uds_addr()), &cfg(12, Some(3)), default_agents(N));
    assert!(quorum.transport_error.is_none(), "{:?}", quorum.transport_error);
    for rec in &quorum.records {
        assert!(rec.absent.is_empty(), "round {}: unexpected absence {:?}", rec.t, rec.absent);
    }
    assert_trace_eq(&full, &quorum, "quorum(4-of-4-answering) vs blocking");
}

/// The flagship recovery property: a worker that crashes mid-session
/// and reconnects is resynced into the very round it abandoned, and
/// the healed session reproduces the uninterrupted reference
/// round-for-round, bit-for-bit — including the billed uplink ledger.
#[test]
fn crash_reconnect_resync_matches_uninterrupted_reference() {
    let c = cfg(10, None);
    let reference = run_session(bind_socket("tcp://127.0.0.1:0"), &c, default_agents(N));
    assert!(reference.transport_error.is_none(), "{:?}", reference.transport_error);

    let mut agents = default_agents(N - 1);
    agents.push(AgentConfig {
        fault: FaultScript::parse("crash@5,reconnect@5").expect("fault grammar"),
        ..AgentConfig::default()
    });
    let healed = run_session(bind_socket("tcp://127.0.0.1:0"), &c, agents);
    assert!(healed.transport_error.is_none(), "{:?}", healed.transport_error);
    // Blocking mode: the rejoined worker answers the crashed round
    // itself, so no round ever records an absence.
    for rec in &healed.records {
        assert!(rec.absent.is_empty(), "round {}: unexpected absence {:?}", rec.t, rec.absent);
    }
    assert_trace_eq(&reference, &healed, "crash@5 + reconnect vs uninterrupted");
}

/// Exhausting the absence budget is a hard failure: the run stops with
/// a `transport_error` naming the worker and the budget, and the
/// partial trace (with its recorded absences) survives for post-mortem.
#[test]
fn absence_budget_exhaustion_surfaces_transport_error() {
    let plan = FaultPlan::new()
        .demote(1, &[2])
        .demote(2, &[2])
        .demote(3, &[2])
        .demote(4, &[2]);
    let c = TrainConfig { absence_budget: 2, ..cfg(10, Some(3)) };
    let sock = bind_socket(&uds_addr()).fault_plan(plan);
    let r = run_session(sock, &c, default_agents(N));
    match &r.transport_error {
        Some(TransportError::Io(m)) => {
            assert!(m.contains("absence budget"), "unexpected message: {m}");
            assert!(m.contains("worker 2"), "unexpected message: {m}");
        }
        other => panic!("expected an io error, got {other:?}"),
    }
    // Rounds before the breach completed and kept their absence record.
    assert_eq!(absent_at(&r, 1), vec![2]);
    assert_eq!(absent_at(&r, 2), vec![2]);
    assert!(r.records.iter().all(|rec| rec.t != 3), "round 3 must not have completed");
}
