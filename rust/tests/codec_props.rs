//! Property tests for the wire codec: CVec/Update encode→decode
//! round-trips (including the sparse→dense cap crossover) and the
//! measured-bytes vs declared-`wire_bits` agreement for every mechanism
//! the spec grammar can produce.

use threepc::compressors::{
    index_bits, parse_contractive, CVec, Contractive, Ctx, CtxInfo, WireValueCoding,
};
use threepc::coordinator::protocol::{frame_overhead_bytes, wire_part_count};
use threepc::coordinator::{decode_uplink, encode_uplink, UplinkMsg};
use threepc::mechanisms::{parse_mechanism, update_bits, MechWorker, ReplaceWire, Update};
use threepc::util::rng::Pcg64;

fn random_cvec(rng: &mut Pcg64, dim: usize) -> CVec {
    match rng.below(3) {
        0 => CVec::Zero { dim },
        1 => CVec::Dense((0..dim).map(|_| rng.normal() as f32).collect()),
        _ => {
            let nnz = rng.below(dim) + 1;
            let idx: Vec<u32> = rng.sample_indices(dim, nnz).into_iter().map(|i| i as u32).collect();
            let val: Vec<f32> = (0..nnz).map(|_| rng.normal() as f32).collect();
            CVec::Sparse { dim, idx, val }
        }
    }
}

fn below_crossover(c: &CVec) -> bool {
    match c {
        CVec::Sparse { dim, idx, .. } => {
            (idx.len() as u64) * (32 + index_bits(*dim)) < 32 * *dim as u64
        }
        _ => true,
    }
}

/// Round-trips preserve the represented vector exactly; sparse frames
/// below the cap crossover preserve the representation too, while
/// frames at/past it decode as the (equally priced) dense form.
#[test]
fn cvec_roundtrip_fuzz() {
    let mut rng = Pcg64::seed(0xc0dec);
    for case in 0..500 {
        let dim = rng.below(200) + 1;
        let c = random_cvec(&mut rng, dim);
        let mut buf = Vec::new();
        c.encode(&mut buf);
        assert_eq!(buf.len(), c.encoded_len(), "case {case}: {c:?}");
        let mut pos = 0;
        let back = CVec::decode(&buf, &mut pos).unwrap_or_else(|e| panic!("case {case}: {e}"));
        assert_eq!(pos, buf.len(), "case {case}: did not consume the frame");
        if below_crossover(&c) {
            assert_eq!(back, c, "case {case}");
        } else {
            assert!(matches!(back, CVec::Dense(_)), "case {case}: cap crossover must go dense");
            assert_eq!(back.to_dense(), c.to_dense(), "case {case}");
        }
    }
}

/// Byte-level payloads track the declared bit accounting: the only
/// slack is the final index byte's zero padding.
#[test]
fn cvec_encoded_len_tracks_wire_bits() {
    let mut rng = Pcg64::seed(0xb17);
    for case in 0..500 {
        let dim = rng.below(500) + 1;
        let c = random_cvec(&mut rng, dim);
        let header = match &c {
            CVec::Sparse { .. } if below_crossover(&c) => 9,
            _ => 5,
        };
        let payload_bits = ((c.encoded_len() - header) * 8) as u64;
        assert!(payload_bits >= c.wire_bits(), "case {case}: {c:?}");
        assert!(payload_bits - c.wire_bits() < 8, "case {case}: {c:?}");
    }
}

/// The exact cap boundary: one entry below the crossover stays sparse,
/// the crossover itself goes dense at exactly the capped cost.
#[test]
fn cap_crossover_boundary_is_exact() {
    // dim = 16, ib = 4: sparse entry costs 36 bits, dense 512; the
    // crossover sits at nnz ≥ ⌈512/36⌉ = 15 (15·36 = 540 ≥ 512).
    let dim = 16usize;
    let mk = |nnz: usize| CVec::Sparse {
        dim,
        idx: (0..nnz as u32).collect(),
        val: vec![1.0; nnz],
    };
    let below = mk(14);
    assert_eq!(below.wire_bits(), 14 * 36);
    let mut buf = Vec::new();
    below.encode(&mut buf);
    let mut pos = 0;
    assert!(matches!(CVec::decode(&buf, &mut pos).unwrap(), CVec::Sparse { .. }));

    let at = mk(15);
    assert_eq!(at.wire_bits(), 32 * dim as u64, "cap applies");
    let mut buf = Vec::new();
    at.encode(&mut buf);
    assert_eq!(buf.len(), 5 + 4 * dim, "dense encoding at the cap");
    let mut pos = 0;
    assert_eq!(CVec::decode(&buf, &mut pos).unwrap().to_dense(), at.to_dense());
}

/// The fused `compress_encode_into` fast path must be byte-identical
/// to compress-then-encode for every contractive spec the grammar can
/// produce, under both value codings, across k < d, k = d, k > d and
/// the sparse→dense cap-crossover regime — including natural-codable
/// (power-of-two) inputs that take the 9-bit value path. Top-K carries
/// the real override; the rest pin the default method so any future
/// override starts from a passing equivalence.
#[test]
fn fused_compress_encode_matches_two_step_bytes() {
    let specs = [
        "top1",
        "top3",
        "top8",
        "top24",
        "top64",
        "identity",
        "crand4",
        "cperm",
        "bern0.5",
        "sign",
        "scaled-rand4",
        "scaled-perm",
        "scaled-natural",
        "cperm*crand8",
    ];
    let dims = [1usize, 5, 24, 100];
    for spec in specs {
        let c = parse_contractive(spec).unwrap();
        for &d in &dims {
            for coding in [WireValueCoding::RawF32, WireValueCoding::Natural] {
                for pow2 in [false, true] {
                    let mut meta = Pcg64::seed(0xf00d ^ ((d as u64) << 8) ^ spec.len() as u64);
                    let x: Vec<f32> = (0..d)
                        .map(|_| {
                            if pow2 {
                                let e = meta.below(9) as i32 - 4;
                                let s = if meta.below(2) == 0 { 1.0f32 } else { -1.0 };
                                s * (2.0f32).powi(e)
                            } else {
                                meta.normal() as f32
                            }
                        })
                        .collect();
                    let info = CtxInfo { dim: d, n_workers: 1, worker_id: 0 };

                    // Two-step reference.
                    let mut rng_a = Pcg64::new(42, 7);
                    let mut ctx_a = Ctx::new(info, &mut rng_a, 3);
                    let mut cv_a = CVec::Zero { dim: 0 };
                    c.compress_into(&x, &mut ctx_a, &mut cv_a);
                    let mut bytes_a = Vec::new();
                    cv_a.encode_with(coding, &mut bytes_a);

                    // Fused path: identical RNG stream and round seed.
                    let mut rng_b = Pcg64::new(42, 7);
                    let mut ctx_b = Ctx::new(info, &mut rng_b, 3);
                    let mut cv_b = CVec::Zero { dim: 0 };
                    let mut bytes_b = Vec::new();
                    c.compress_encode_into(&x, &mut ctx_b, coding, &mut cv_b, &mut bytes_b);

                    let label = format!("{spec} d={d} coding={coding:?} pow2={pow2}");
                    assert_eq!(bytes_a, bytes_b, "{label}: wire bytes");
                    assert_eq!(
                        cv_a.to_dense(),
                        cv_b.to_dense(),
                        "{label}: represented vector"
                    );
                }
            }
        }
    }
}

/// The declared `bits` of every Replace update equals the wire cost of
/// its decomposition, and the serialized frame's measured payload
/// matches within per-part byte padding — for every mechanism spec the
/// grammar can produce (the `parse_all_specs` set).
#[test]
fn measured_bytes_agree_with_declared_bits_for_all_specs() {
    let specs = [
        "gd",
        "dcgd:top4",
        "ef21:top4",
        "lag:4.0",
        "clag:top4:2.0",
        "v1:top4",
        "v2:rand4:top4",
        "v3:ef21:top4;top2",
        "v4:top4:top2",
        "v5:0.25:top4",
        "marina:0.25:rand4",
    ];
    let d = 24usize;
    let n = 4usize;
    for spec in specs {
        let map = parse_mechanism(spec).unwrap();
        let mut meta = Pcg64::seed(0x5eed ^ spec.len() as u64);
        let g0: Vec<f32> = (0..d).map(|_| meta.normal() as f32).collect();
        let grad0: Vec<f32> = (0..d).map(|_| meta.normal() as f32).collect();
        let mut worker = MechWorker::new(map, g0, grad0);
        let mut rng = Pcg64::new(11, 0x77);
        let info = CtxInfo { dim: d, n_workers: n, worker_id: 0 };
        for t in 0..12u64 {
            let grad: Vec<f32> = (0..d).map(|_| meta.normal() as f32).collect();
            let h_before = worker.g().to_vec();
            let mut ctx = Ctx::new(info, &mut rng, t);
            let (update, g_err) = worker.round(&grad, &mut ctx);

            // Declared invariant: Replace bits == decomposition cost.
            if let Update::Replace { bits, wire, g, .. } = &update {
                assert_eq!(*bits, wire.wire_bits(g.len()), "{spec} round {t}");
                if matches!(wire, ReplaceWire::Dense) {
                    // Dense wire means g itself crosses.
                    assert_eq!(*bits, 32 * g.len() as u64, "{spec} round {t}");
                }
            }

            // Measured agreement through the full frame codec.
            let declared = update_bits(&update);
            let parts = wire_part_count(&update);
            let msg = UplinkMsg { worker_id: 0, update, g_err };
            let bytes = encode_uplink(&msg);
            let payload_bits = 8 * (bytes.len() - frame_overhead_bytes(&msg.update)) as u64;
            assert!(
                payload_bits >= declared,
                "{spec} round {t}: payload {payload_bits} < declared {declared}"
            );
            assert!(
                payload_bits - declared < 8 * parts.max(1) as u64,
                "{spec} round {t}: payload {payload_bits} vs declared {declared} ({parts} parts)"
            );

            // And the decoded frame reconstructs the exact new state.
            let decoded = decode_uplink(&bytes).unwrap();
            let rebuilt = decoded.update.new_state(&h_before);
            assert_eq!(rebuilt.len(), d);
            for (i, (a, b)) in rebuilt.iter().zip(worker.g()).enumerate() {
                assert!(a == b, "{spec} round {t}: coord {i}: {a} vs {b}");
            }
        }
    }
}
