//! Property and acceptance tests for the mechanism-schedule axis:
//!
//! * `Static(m)` and a degenerate one-entry `Piecewise` are bit-for-bit
//!   identical to a fixed-mechanism run, for every spec the grammar can
//!   produce, on both transports;
//! * a `Piecewise` switch mid-run produces a `Framed` trace whose
//!   measured downlink bytes include the `MechSwitch` frames, agrees
//!   with the declared accounting, and matches the `InProcess` trace
//!   round-for-round;
//! * `AdaptiveGrad` demonstrably switches on the quadratic suite and is
//!   logged in the trace and the `ScheduleObserver`;
//! * a killed-and-resumed session reproduces the reference trace.

use threepc::coordinator::{
    encode_mech_switch, Checkpoint, CheckpointObserver, Framed, InProcess, InitPolicy, MechSwitch,
    ScheduleObserver, TrainConfig, TrainResult, TrainSession,
};
use threepc::mechanisms::parse_mechanism;
use threepc::problems::quadratic;

/// Every spec `parse_all_specs` pins down.
const ALL_SPECS: [&str; 11] = [
    "gd",
    "dcgd:top3",
    "ef21:top3",
    "lag:2.0",
    "clag:top3:2.0",
    "v1:top3",
    "v2:rand3:top3",
    "v3:ef21:top3;top2",
    "v4:top3:top2",
    "v5:0.3:top3",
    "marina:0.3:rand3",
];

fn base_cfg(rounds: usize) -> TrainConfig {
    // threads = 1 pins the f64 fold order so traces compare exactly.
    TrainConfig { gamma: 0.02, max_rounds: rounds, threads: 1, seed: 13, ..TrainConfig::default() }
}

fn assert_identical(a: &TrainResult, b: &TrainResult, label: &str) {
    assert_eq!(a.rounds_run, b.rounds_run, "{label}: rounds");
    assert_eq!(a.records.len(), b.records.len(), "{label}: record count");
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.t, rb.t, "{label}");
        assert_eq!(ra.grad_norm_sq, rb.grad_norm_sq, "{label} round {}", ra.t);
        assert_eq!(ra.g_err, rb.g_err, "{label} round {}", ra.t);
        assert_eq!(ra.bits_up_cum, rb.bits_up_cum, "{label} round {}", ra.t);
        assert_eq!(ra.bits_up_max, rb.bits_up_max, "{label} round {}", ra.t);
        assert_eq!(ra.bits_down_cum, rb.bits_down_cum, "{label} round {}", ra.t);
        assert_eq!(ra.skipped_frac, rb.skipped_frac, "{label} round {}", ra.t);
        assert_eq!(ra.mech_switch, rb.mech_switch, "{label} round {}", ra.t);
    }
    assert_eq!(a.total_bits_up, b.total_bits_up, "{label}");
    assert_eq!(a.total_bits_down, b.total_bits_down, "{label}");
    assert_eq!(a.wire_bytes_up, b.wire_bytes_up, "{label}");
    assert_eq!(a.wire_bytes_down, b.wire_bytes_down, "{label}");
    assert_eq!(a.final_x, b.final_x, "{label}");
}

/// `Static(m)` (what `.schedule_spec(spec)` builds for a bare mechanism
/// spec) and a degenerate one-entry `Piecewise` must be bit-for-bit
/// identical to today's fixed-mechanism runs, for every spec in the
/// grammar, on both transports.
#[test]
fn static_and_degenerate_piecewise_match_fixed_mechanism_runs() {
    let suite = quadratic::generate(6, 30, 1e-2, 0.5, 21);
    for spec in ALL_SPECS {
        for framed in [false, true] {
            let run = |builder: threepc::coordinator::SessionBuilder<'_>| {
                let builder = builder.config(base_cfg(25));
                if framed {
                    builder.transport(Framed::default()).run()
                } else {
                    builder.transport(InProcess::new(1)).run()
                }
            };
            let fixed = run(TrainSession::builder(&suite.problem)
                .mechanism(parse_mechanism(spec).unwrap()));
            let statik = run(TrainSession::builder(&suite.problem)
                .schedule_spec(spec)
                .unwrap());
            let degenerate = run(TrainSession::builder(&suite.problem)
                .schedule_spec(&format!("{spec}@0.."))
                .unwrap());
            let label = format!("{spec} (framed={framed})");
            assert_identical(&fixed, &statik, &format!("static vs fixed: {label}"));
            assert_identical(&fixed, &degenerate, &format!("piecewise vs fixed: {label}"));
            // No switches anywhere, and nothing on the downlink wire.
            assert!(fixed.mech_switches().is_empty(), "{label}");
            assert!(degenerate.mech_switches().is_empty(), "{label}");
            assert_eq!(degenerate.wire_bytes_down, 0, "{label}");
        }
    }
}

/// The ISSUE acceptance scenario: a `Piecewise` schedule switching a
/// Top-K mechanism to EF21 mid-run. The `Framed` trace must include the
/// `MechSwitch` frame in its measured downlink bytes, agree with the
/// declared accounting, and match the `InProcess` trace round-for-round.
#[test]
fn piecewise_switch_framed_matches_inprocess_and_bills_the_directive() {
    let suite = quadratic::generate(6, 30, 1e-2, 0.5, 21);
    let sched = "clag:top4:2.0@0..15,ef21:top4@15..";
    let rounds = 30;
    let a = TrainSession::builder(&suite.problem)
        .schedule_spec(sched)
        .unwrap()
        .config(base_cfg(rounds))
        .transport(InProcess::new(1))
        .run();
    let b = TrainSession::builder(&suite.problem)
        .schedule_spec(sched)
        .unwrap()
        .config(base_cfg(rounds))
        .transport(Framed::default())
        .run();

    // Round-for-round trajectory equality across transports.
    assert_eq!(a.rounds_run, b.rounds_run);
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.grad_norm_sq, rb.grad_norm_sq, "round {}", ra.t);
        assert_eq!(ra.g_err, rb.g_err, "round {}", ra.t);
        assert_eq!(ra.skipped_frac, rb.skipped_frac, "round {}", ra.t);
        assert_eq!(ra.bits_down_cum, rb.bits_down_cum, "round {}", ra.t);
        assert_eq!(ra.mech_switch, rb.mech_switch, "round {}", ra.t);
    }

    // Exactly one switch, at round 15, to EF21 — recorded in the trace.
    let ef21_name = parse_mechanism("ef21:top4").unwrap().name();
    assert_eq!(a.mech_switches(), vec![(15, ef21_name.clone())]);
    assert_eq!(b.mech_switches(), a.mech_switches());

    // The Framed transport put the directive on the wire for real, and
    // its measured bytes agree with the declared billing.
    let frame = encode_mech_switch(&MechSwitch {
        round: 15,
        mech: ef21_name,
        spec: parse_mechanism("ef21:top4").unwrap().spec(),
    })
    .unwrap();
    assert_eq!(b.wire_bytes_down, frame.len() as u64);
    assert_eq!(a.wire_bytes_down, 0, "in-memory transport serializes nothing");
    let dense_broadcast_bits = (rounds * 32 * 30) as u64; // rounds × 32·d
    assert_eq!(b.total_bits_down, dense_broadcast_bits + 8 * b.wire_bytes_down);
    assert_eq!(a.total_bits_down, b.total_bits_down, "declared billing matches measured");
}

/// `AdaptiveGrad` must demonstrably switch mechanisms on the quadratic
/// suite, log the switch in `RoundRecord`, and feed the
/// `ScheduleObserver`.
#[test]
fn adaptive_schedule_switches_on_the_quadratic_suite_and_is_logged() {
    let suite = quadratic::generate(8, 40, 5e-2, 0.5, 5);
    let mut c = base_cfg(80);
    // Zero init gives a large G⁰, so the EF21 transient contracts G^t
    // hard between decision windows and the ladder escalates.
    c.gamma = 1e-3;
    c.init = InitPolicy::Zero;
    let obs = ScheduleObserver::new();
    let log = obs.log();
    let r = TrainSession::builder(&suite.problem)
        .schedule_spec("adaptive@5:ef21:top8|ef21:top1")
        .unwrap()
        .config(c)
        .observer(obs)
        .run();
    assert_eq!(r.rounds_run, 80);

    let switches = r.mech_switches();
    assert!(!switches.is_empty(), "adaptive schedule must switch at least once");
    let top1_name = parse_mechanism("ef21:top1").unwrap().name();
    assert_eq!(switches[0].1, top1_name, "first move escalates to the aggressive rung");
    assert!(switches[0].0 >= 10, "a decision needs two windows (baseline + compare)");

    let logged = log.lock().expect("switch log");
    assert_eq!(logged[0].0, 0, "the initial mechanism is logged at the first round");
    assert_eq!(logged[0].1, parse_mechanism("ef21:top8").unwrap().name());
    assert_eq!(logged.len(), switches.len() + 1, "observer log = initial + every switch");
    for (w, s) in logged.iter().skip(1).zip(&switches) {
        assert_eq!((w.0, w.1.clone()), (s.0, s.1.clone()));
    }
}

/// Kill-and-resume: a session resumed from a `CheckpointObserver` file
/// reproduces the uninterrupted reference trace round-for-round (the
/// checkpoint carries the exact leader fold state, and round seeds are
/// keyed to absolute round numbers).
#[test]
fn kill_and_resume_reproduces_the_reference_trace() {
    let suite = quadratic::generate(6, 24, 1e-2, 0.5, 7);
    let c = TrainConfig {
        gamma: 0.02,
        max_rounds: 30,
        threads: 1,
        seed: 13,
        ..TrainConfig::default()
    };
    let reference = TrainSession::builder(&suite.problem)
        .mechanism(parse_mechanism("clag:top3:2.0").unwrap())
        .config(c.clone())
        .run();

    // The "killed" run: cut at round 15, having checkpointed at 14.
    let path = std::env::temp_dir().join(format!("threepc-resume-{}.bin", std::process::id()));
    let mut killed_cfg = c.clone();
    killed_cfg.max_rounds = 15;
    let killed = TrainSession::builder(&suite.problem)
        .mechanism(parse_mechanism("clag:top3:2.0").unwrap())
        .config(killed_cfg)
        .observer(CheckpointObserver::new(14, path.clone()))
        .run();
    assert_eq!(killed.rounds_run, 15);
    let cp = Checkpoint::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(cp.t, 14);

    // Resume to the same horizon and compare with the reference tail.
    let resumed = TrainSession::resume(&suite.problem, &cp)
        .unwrap()
        .mechanism(parse_mechanism("clag:top3:2.0").unwrap())
        .config(c)
        .run();
    assert_eq!(resumed.rounds_run, 30, "the round clock is cumulative across the resume");
    let tail: Vec<_> = reference.records.iter().filter(|r| r.t >= 15).collect();
    assert_eq!(resumed.records.len(), tail.len());
    for (rr, tr) in resumed.records.iter().zip(&tail) {
        assert_eq!(rr.t, tr.t);
        assert_eq!(rr.grad_norm_sq, tr.grad_norm_sq, "round {}", rr.t);
        assert_eq!(rr.g_err, tr.g_err, "round {}", rr.t);
        assert_eq!(rr.skipped_frac, tr.skipped_frac, "round {}", rr.t);
        assert_eq!(rr.bits_up_cum, tr.bits_up_cum, "round {}", rr.t);
        assert_eq!(rr.bits_down_cum, tr.bits_down_cum, "round {}", rr.t);
    }
    assert_eq!(resumed.final_x, reference.final_x);
    // The checkpoint carries the bit ledger: the resumed run's
    // cumulative totals equal the undisturbed reference's (the resume
    // itself bills nothing).
    assert_eq!(resumed.total_bits_up, reference.total_bits_up);
    assert_eq!(resumed.total_bits_down, reference.total_bits_down);
}

/// Natural value coding is transparent to the trajectory (lossless for
/// power-of-two payloads, raw fallback otherwise) and strictly cheaper
/// in measured bytes for natural-compressed mechanisms.
#[test]
fn natural_value_coding_matches_raw_trace_with_fewer_bytes() {
    let suite = quadratic::generate(5, 20, 1e-2, 0.5, 3);
    let spec = "marina:0.2:natural";
    let raw = TrainSession::builder(&suite.problem)
        .mechanism(parse_mechanism(spec).unwrap())
        .config(base_cfg(20))
        .transport(Framed::new())
        .run();
    let nat = TrainSession::builder(&suite.problem)
        .mechanism(parse_mechanism(spec).unwrap())
        .config(base_cfg(20))
        .transport(Framed::natural())
        .run();
    assert_eq!(raw.rounds_run, nat.rounds_run);
    for (ra, rb) in raw.records.iter().zip(&nat.records) {
        assert_eq!(ra.grad_norm_sq, rb.grad_norm_sq, "round {}", ra.t);
        assert_eq!(ra.g_err, rb.g_err, "round {}", ra.t);
        assert_eq!(ra.skipped_frac, rb.skipped_frac, "round {}", ra.t);
    }
    assert_eq!(raw.final_x, nat.final_x, "value coding must not change the trajectory");
    assert!(
        nat.wire_bytes_up < raw.wire_bytes_up,
        "natural coding must shrink the measured uplink ({} vs {})",
        nat.wire_bytes_up,
        raw.wire_bytes_up
    );
}
