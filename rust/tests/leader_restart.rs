//! Crash-safe leader acceptance, end to end over the real `threepc`
//! binary: a leader SIGKILLed mid-run and restarted — solo with
//! `--resume-from`, or as a `--journal`ed daemon — must reproduce the
//! undisturbed reference run's `result-bits:` line bit for bit (rounds,
//! final gradient norm, billed bits, measured wire bytes), with the
//! surviving worker processes re-attaching on their own under
//! `--reattach`.
#![cfg(unix)]

use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use threepc::coordinator::Checkpoint;

const N: usize = 4;
const ROUNDS: usize = 400;

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_threepc")
}

/// A scratch directory unique to this test process.
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("3pc-lr-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("scratch dir");
    dir
}

/// Kill-on-drop child guard: a panicking test must not leak worker
/// processes that retry forever under `--reattach`.
struct Proc(Child);

impl Drop for Proc {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

fn spawn(args: &[&str]) -> Child {
    Command::new(bin())
        .args(args)
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn threepc")
}

fn spawn_captured(args: &[&str]) -> Child {
    Command::new(bin())
        .args(args)
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn threepc")
}

/// The shared `train` argument tail: problem geometry, mechanism and
/// horizon are identical across the reference, the killed run and the
/// resumed run, so their traces are comparable bit for bit.
fn train_args(addr: &str) -> Vec<String> {
    [
        "train",
        "--problem",
        "quad",
        "--workers",
        "4",
        "--d",
        "30",
        "--lambda",
        "0.01",
        "--noise-scale",
        "0.5",
        "--seed",
        "21",
        "--mech",
        "ef21:top3",
        "--gamma",
        "0.02",
        "--rounds",
        "400",
        "--transport",
        addr,
    ]
    .iter()
    .map(|s| s.to_string())
    .collect()
}

fn worker_args(addr: &str) -> Vec<String> {
    [
        "worker",
        "--connect",
        addr,
        "--reattach=true",
        // The delay paces rounds (≥ 2 ms each) so the kill lands
        // mid-run deterministically; it cannot change the trace.
        "--reply-delay-ms",
        "2",
        "--retries",
        "100000",
        "--retry-backoff-ms",
        "20",
        "--retry-backoff-max-ms",
        "200",
        "--io-timeout-ms",
        "60000",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect()
}

fn result_bits(stdout: &str) -> String {
    stdout
        .lines()
        .find(|l| l.starts_with("result-bits:"))
        .unwrap_or_else(|| panic!("no result-bits line in:\n{stdout}"))
        .to_string()
}

/// Block until the child exits successfully and return its stdout.
fn wait_success(child: Child, what: &str) -> String {
    let out = child.wait_with_output().unwrap_or_else(|e| panic!("{what}: {e}"));
    let stdout = String::from_utf8_lossy(&out.stdout).into_owned();
    assert!(
        out.status.success(),
        "{what} failed ({}):\n{stdout}\n{}",
        out.status,
        String::from_utf8_lossy(&out.stderr)
    );
    stdout
}

/// Poll the checkpoint file until it holds a committed round ≥ `min_t`
/// (atomic persists mean a load never sees a torn file).
fn wait_ckpt_round(path: &Path, min_t: usize) -> usize {
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        if let Ok(cp) = Checkpoint::load(path) {
            if cp.t >= min_t {
                return cp.t;
            }
        }
        assert!(
            Instant::now() < deadline,
            "checkpoint {} never reached round {min_t}",
            path.display()
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// The undisturbed reference: one solo leader over its own socket with
/// in-process loopback agents, run to the full horizon.
fn reference_result_bits(dir: &Path) -> String {
    let addr = format!("uds://{}", dir.join("ref.sock").display());
    let mut args = train_args(&addr);
    args.push("--spawn-workers=true".into());
    let argv: Vec<&str> = args.iter().map(|s| s.as_str()).collect();
    let stdout = wait_success(spawn_captured(&argv), "reference train");
    result_bits(&stdout)
}

#[test]
fn sigkilled_solo_leader_resumes_bit_for_bit_and_workers_reattach() {
    let dir = scratch("solo");
    let reference = reference_result_bits(&dir);

    // The doomed leader: external worker processes, periodic
    // checkpoints, SIGKILL once round 50 is committed on disk.
    let addr = format!("uds://{}", dir.join("run.sock").display());
    let ckpt = dir.join("leader.ckpt");
    let mut args = train_args(&addr);
    args.extend(["--checkpoint".into(), ckpt.display().to_string()]);
    args.extend(["--checkpoint-every".into(), "25".into()]);
    let argv: Vec<&str> = args.iter().map(|s| s.as_str()).collect();
    let mut doomed = Proc(spawn(&argv));
    let wargs = worker_args(&addr);
    let wargv: Vec<&str> = wargs.iter().map(|s| s.as_str()).collect();
    let workers: Vec<Proc> = (0..N).map(|_| Proc(spawn(&wargv))).collect();
    let killed_at = wait_ckpt_round(&ckpt, 50);
    assert!(killed_at < ROUNDS, "the kill must land mid-run");
    doomed.0.kill().expect("SIGKILL leader");
    doomed.0.wait().expect("reap leader");

    // The restarted leader re-binds the same address and resumes from
    // the checkpoint; the orphaned workers re-dial it on their own.
    let mut args = train_args(&addr);
    args.extend(["--resume-from".into(), ckpt.display().to_string()]);
    args.extend(["--checkpoint".into(), ckpt.display().to_string()]);
    args.extend(["--checkpoint-every".into(), "25".into()]);
    let argv: Vec<&str> = args.iter().map(|s| s.as_str()).collect();
    let stdout = wait_success(spawn_captured(&argv), "resumed train");
    assert!(stdout.contains("resuming from"), "resume banner missing:\n{stdout}");
    assert_eq!(
        result_bits(&stdout),
        reference,
        "the resumed run must reproduce the reference result and ledger exactly"
    );

    // The leader's shutdown frames end the re-attached workers cleanly.
    let deadline = Instant::now() + Duration::from_secs(30);
    for mut w in workers {
        loop {
            match w.0.try_wait().expect("poll worker") {
                Some(status) => {
                    assert!(status.success(), "worker exited with {status}");
                    break;
                }
                None => {
                    assert!(Instant::now() < deadline, "worker never shut down");
                    std::thread::sleep(Duration::from_millis(20));
                }
            }
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Probe a daemon: a structured reject on a bogus id proves the
/// control plane is up (a refused connection does not print one).
fn wait_daemon_ready(addr: &str) {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let out = Command::new(bin())
            .args(["status", "--connect", addr, "--id", "999999"])
            .output()
            .expect("run status probe");
        if String::from_utf8_lossy(&out.stderr).contains("rejected") {
            return;
        }
        assert!(Instant::now() < deadline, "daemon at {addr} never came up");
        std::thread::sleep(Duration::from_millis(50));
    }
}

#[test]
fn sigkilled_journaled_daemon_resumes_its_session_bit_for_bit() {
    let dir = scratch("daemon");
    let reference = reference_result_bits(&dir);

    let addr = format!("uds://{}", dir.join("daemon.sock").display());
    let journal = dir.join("sessions.journal");
    let ckpt = dir.join("daemon.ckpt");
    let serve_args: Vec<String> = [
        "serve",
        "--listen",
        &addr,
        "--fleet",
        "4",
        "--journal",
        &journal.display().to_string(),
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    let serve_argv: Vec<&str> = serve_args.iter().map(|s| s.as_str()).collect();
    let mut daemon = Proc(spawn(&serve_argv));
    wait_daemon_ready(&addr);

    // External worker processes form the fleet (their reply delay
    // paces the rounds; their --reattach outlives the daemon).
    let wargs = worker_args(&addr);
    let wargv: Vec<&str> = wargs.iter().map(|s| s.as_str()).collect();
    let workers: Vec<Proc> = (0..N).map(|_| Proc(spawn(&wargv))).collect();

    // The same run as the reference, as a daemon session spec.
    let spec = format!(
        "problem=quad:4:30:0.01:0.5:21;mech=ef21:top3;rounds={ROUNDS};gamma=0.02;seed=21;\
         checkpoint={};checkpoint-every=25",
        ckpt.display()
    );
    let submit = Command::new(bin())
        .args(["submit", "--connect", &addr, "--spec", &spec])
        .output()
        .expect("submit");
    assert!(
        submit.status.success(),
        "submit failed:\n{}",
        String::from_utf8_lossy(&submit.stderr)
    );

    // SIGKILL the daemon once round 50 is committed; the journal's
    // last words are the admission and that checkpoint.
    let killed_at = wait_ckpt_round(&ckpt, 50);
    assert!(killed_at < ROUNDS, "the kill must land mid-run");
    daemon.0.kill().expect("SIGKILL daemon");
    daemon.0.wait().expect("reap daemon");

    // A fresh daemon on the same journal re-admits the session and
    // resumes it from the checkpoint; the orphaned workers re-dial
    // into its fleet and are installed over the resync path.
    let mut daemon = Proc(spawn(&serve_argv));
    wait_daemon_ready(&addr);
    let attach = Command::new(bin())
        .args(["attach", "--connect", &addr, "--id", "1"])
        .output()
        .expect("attach");
    let stdout = String::from_utf8_lossy(&attach.stdout).into_owned();
    assert!(
        attach.status.success(),
        "attach failed:\n{stdout}\n{}",
        String::from_utf8_lossy(&attach.stderr)
    );
    assert_eq!(
        result_bits(&stdout),
        reference,
        "the journal-resumed session must reproduce the reference result and ledger exactly"
    );

    daemon.0.kill().expect("stop daemon");
    daemon.0.wait().expect("reap daemon");
    drop(workers);
    let _ = std::fs::remove_dir_all(&dir);
}
