//! Integration tests across coordinator + mechanisms + problems:
//! convergence behaviour, rate shapes, reduction identities, and
//! bit-accounting invariants on full training runs (native backend —
//! the HLO-path equivalents live in integration_runtime.rs).

use std::sync::Arc;
use threepc::coordinator::{InitPolicy, TrainConfig, TrainResult, TrainSession};
use threepc::data;
use threepc::experiments::common;
use threepc::mechanisms::{parse_mechanism, ThreePointMap};
use threepc::problems::quadratic;
use threepc::problems::{Distributed, LocalProblem};
use threepc::util::stats;

fn cfg(gamma: f64, rounds: usize) -> TrainConfig {
    TrainConfig { gamma, max_rounds: rounds, seed: 77, ..TrainConfig::default() }
}

/// All runs in this file go through the session API (the `train()` free
/// function survives only as a deprecated shim).
fn train(problem: &Distributed, map: Arc<dyn ThreePointMap>, cfg: &TrainConfig) -> TrainResult {
    TrainSession::builder(problem).mechanism(map).config(cfg.clone()).run()
}

/// Theorem 5.8 made measurable: every 3PC method at its theoretical PŁ
/// stepsize contracts the gradient norm geometrically on the quadratic
/// suite.
#[test]
fn all_methods_converge_linearly_under_pl() {
    let suite = quadratic::generate(6, 60, 5e-2, 0.5, 3);
    let s = suite.problem.smoothness.unwrap();
    let mu = suite.mu;
    for spec in [
        "gd",
        "ef21:top6",
        "lag:4.0",
        "clag:top6:4.0",
        "v1:top6",
        "v2:rand6:top6",
        "v3:ef21:top6;top6",
        "v4:top6:top6",
        "v5:0.3:top6",
        "marina:0.3:rand6",
    ] {
        let map = parse_mechanism(spec).unwrap();
        let info = threepc::compressors::CtxInfo { dim: 60, n_workers: 6, worker_id: 0 };
        let params = map.params(&info).unwrap();
        let gamma = threepc::theory::stepsize_pl(params, s, mu);
        let r = train(&suite.problem, map, &cfg(gamma, 2500));
        assert!(!r.diverged, "{spec} diverged");
        let gns: Vec<f64> = r.records.iter().map(|rec| rec.grad_norm_sq).collect();
        let factor = stats::linear_rate_factor(&gns, 1e-22).unwrap_or(1.0);
        assert!(
            factor < 0.9999,
            "{spec}: no linear contraction (factor {factor}), final {}",
            r.final_grad_norm_sq
        );
        // The compression error G^t must decay along with convergence
        // (the defining 3PC property, Eq. 9).
        let g_first = r.records[2].g_err;
        let g_last = r.records.last().unwrap().g_err;
        assert!(
            g_last < g_first * 0.5 || g_last < 1e-12,
            "{spec}: G^t did not decay ({g_first} → {g_last})"
        );
    }
}

/// The reduction identities of §4.5 hold for *whole training runs*, not
/// just single applications: CLAG(ζ=0) ≡ EF21 and CLAG(identity) ≡ LAG
/// trace-for-trace (same seeds).
#[test]
fn clag_reductions_hold_over_full_runs() {
    let suite = quadratic::generate(5, 40, 1e-2, 0.8, 9);
    let c = cfg(0.05, 120);
    let ef = train(&suite.problem, parse_mechanism("ef21:top4").unwrap(), &c);
    let clag0 = train(&suite.problem, parse_mechanism("clag:top4:0.0").unwrap(), &c);
    for (a, b) in ef.records.iter().zip(&clag0.records) {
        assert_eq!(a.grad_norm_sq, b.grad_norm_sq, "round {}", a.t);
    }
    let lag = train(&suite.problem, parse_mechanism("lag:4.0").unwrap(), &c);
    let clag_id = train(&suite.problem, parse_mechanism("clag:identity:4.0").unwrap(), &c);
    for (a, b) in lag.records.iter().zip(&clag_id.records) {
        // LAG folds Replace deltas in f64 while CLAG(identity) emits f32
        // increments — identical semantics up to one f32 rounding.
        let rel = (a.grad_norm_sq - b.grad_norm_sq).abs() / (1e-300 + a.grad_norm_sq);
        assert!(rel < 1e-6, "round {}: {} vs {}", a.t, a.grad_norm_sq, b.grad_norm_sq);
        // identical updates → identical payload bits
        assert_eq!(a.bits_up_cum, b.bits_up_cum, "round {}", a.t);
    }
}

/// Naive DCGD with aggressive Top-K stalls at a plateau that EF21 (same
/// compressor, 3PC mechanism) breaks through — §2.1's motivation.
#[test]
fn ef21_fixes_dcgd_stall() {
    let suite = quadratic::generate(6, 50, 5e-2, 0.0, 5);
    let gamma = 0.2 / suite.l_minus;
    let dcgd = train(&suite.problem, parse_mechanism("dcgd:top1").unwrap(), &cfg(gamma, 1500));
    let ef21 = train(&suite.problem, parse_mechanism("ef21:top1").unwrap(), &cfg(gamma, 1500));
    assert!(
        ef21.final_grad_norm_sq < dcgd.final_grad_norm_sq * 1e-2,
        "EF21 {} should beat DCGD {} by ≫100x",
        ef21.final_grad_norm_sq,
        dcgd.final_grad_norm_sq
    );
}

/// Lazy aggregation saves uplink bits on logreg relative to GD at equal
/// tolerance (the Figures 21–24 shape).
#[test]
fn lazy_methods_save_bits_on_logreg() {
    let ds = data::synthetic_libsvm("ijcnn1", false, 3).unwrap();
    let problem = common::logreg_problem(&ds, 8, 0.1, 1);
    let tol = 0.2; // ‖∇f‖ target reachable by all methods within the round cap
    let mut bits = std::collections::HashMap::new();
    for spec in ["gd", "clag:top5:16.0"] {
        let map = parse_mechanism(spec).unwrap();
        let base = common::base_gamma(&problem, map.as_ref());
        let tuned = common::tune_stepsize(
            &problem,
            map,
            base,
            &[4.0, 16.0, 64.0, 256.0, 1024.0],
            &TrainConfig { max_rounds: 3000, grad_tol: Some(tol), seed: 5, ..TrainConfig::default() },
            common::Criterion::MinBitsToTol(tol),
        );
        bits.insert(spec, tuned.score.expect(spec));
    }
    assert!(
        bits["clag:top5:16.0"] < bits["gd"] * 0.7,
        "CLAG {} not clearly cheaper than GD {}",
        bits["clag:top5:16.0"],
        bits["gd"]
    );
}

/// Zero-init g⁰ still converges (§4.2 option c) and bills no init bits.
#[test]
fn zero_init_converges() {
    let suite = quadratic::generate(4, 30, 5e-2, 0.2, 11);
    let mut c = cfg(0.1 / suite.l_minus, 2500);
    c.init = InitPolicy::Zero;
    c.grad_tol = Some(1e-3);
    let r = train(&suite.problem, parse_mechanism("ef21:top3").unwrap(), &c);
    assert!(r.converged, "final {}", r.final_grad_norm_sq);
    // First record's bits must be strictly less than full-gradient init.
    let first = &r.records[0];
    assert!(first.bits_up_cum < 32.0 * 30.0 + 64.0);
}

/// Determinism: identical seeds give identical traces; different seeds
/// differ (randomized mechanisms).
#[test]
fn seeded_reproducibility() {
    let suite = quadratic::generate(4, 30, 1e-2, 0.5, 17);
    let mk = || parse_mechanism("v2:rand3:top3").unwrap();
    let a = train(&suite.problem, mk(), &cfg(0.05, 60));
    let b = train(&suite.problem, mk(), &cfg(0.05, 60));
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_eq!(x.grad_norm_sq, y.grad_norm_sq);
    }
    let mut c2 = cfg(0.05, 60);
    c2.seed = 123;
    let c = train(&suite.problem, mk(), &c2);
    assert!(
        a.records
            .iter()
            .zip(&c.records)
            .any(|(x, y)| x.grad_norm_sq != y.grad_norm_sq),
        "different seeds must perturb randomized runs"
    );
}

/// The LAG skip-rate increases with ζ (monotone trigger behaviour).
#[test]
fn skip_rate_monotone_in_zeta() {
    let suite = quadratic::generate(6, 40, 1e-2, 0.5, 19);
    let mut last = -1.0;
    for zeta in [0.5, 4.0, 32.0, 256.0] {
        let r = train(
            &suite.problem,
            parse_mechanism(&format!("lag:{zeta}")).unwrap(),
            &cfg(0.02, 150),
        );
        let rate = r.mean_skip_rate();
        assert!(rate >= last - 0.05, "zeta {zeta}: skip {rate} vs prev {last}");
        last = rate;
    }
    assert!(last > 0.5, "large zeta should skip most rounds ({last})");
}

/// The typed quadratic handles and the distributed problem's trait
/// objects alias the same locals.
#[test]
fn quad_suite_handles_alias() {
    let suite = quadratic::generate(3, 10, 1e-2, 0.3, 21);
    for (q, l) in suite.locals.iter().zip(&suite.problem.locals) {
        let x = vec![0.5f32; 10];
        let mut a = vec![0.0f32; 10];
        let mut b = vec![0.0f32; 10];
        q.grad(&x, &mut a);
        l.grad(&x, &mut b);
        assert_eq!(a, b);
    }
}

/// Scale check: n = 200 workers through the threaded orchestrator.
#[test]
fn scales_to_many_workers() {
    let suite = quadratic::generate(200, 50, 1e-2, 0.5, 23);
    let r = train(&suite.problem, parse_mechanism("clag:top2:8.0").unwrap(), &cfg(0.05, 30));
    assert_eq!(r.records.len(), 30);
    assert!(!r.diverged);
    let _: &Arc<dyn LocalProblem> = &suite.problem.locals[0];
}
