//! Acceptance suite for the `threepc serve` daemon: sessions submitted
//! over the client protocol must reproduce their solo `Socket` traces
//! bit-for-bit even while other sessions share the daemon and its
//! worker fleet; malformed submissions must come back as structured
//! rejects; cancel must free the fleet for the next session; and a
//! shutdown request must drain running sessions at a round boundary,
//! checkpointing where configured.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use threepc::coordinator::protocol::ROUND_PAYLOAD_BYTES;
use threepc::coordinator::socket::quad_problem_spec;
use threepc::coordinator::{
    run_worker_agent, AgentConfig, Checkpoint, RejectCode, RoundRecord, ServeFrame, ServeOptions,
    Service, ServiceClient, SessionPhase, SessionResult, SessionSpec, Socket, TrainResult,
    TrainSession,
};
use threepc::problems::quadratic;

const N: usize = 4;
const D: usize = 30;
const LAMBDA: f64 = 1e-2;
const NOISE: f64 = 0.5;
const QSEED: u64 = 21;

fn problem_spec() -> String {
    quad_problem_spec(N, D, LAMBDA, NOISE, QSEED)
}

fn spec_ef21() -> String {
    format!("problem={};mech=ef21:top3;rounds=40;gamma=0.02;seed=13", problem_spec())
}

fn spec_clag() -> String {
    format!("problem={};mech=clag:top3:2.0;rounds=40;gamma=0.02;seed=13", problem_spec())
}

fn spec_switch() -> String {
    format!(
        "problem={};schedule=ef21:top8@0..12,ef21:top2@12..;rounds=24;gamma=0.02;seed=13",
        problem_spec()
    )
}

fn spawn_agents(addr: &str, n: usize) -> Vec<thread::JoinHandle<anyhow::Result<()>>> {
    (0..n)
        .map(|_| {
            let a = addr.to_string();
            thread::spawn(move || run_worker_agent(&a, &AgentConfig::default()))
        })
        .collect()
}

/// The reference: the same spec run through a dedicated solo `Socket`
/// leader, configured via the *same* parsed `SessionSpec` the daemon
/// would build, so any divergence is the daemon's doing.
fn solo_reference(spec: &str) -> TrainResult {
    let parsed = SessionSpec::parse(spec, None).expect("valid spec");
    let suite = quadratic::generate(N, D, LAMBDA, NOISE, QSEED);
    let sock = Socket::bind("tcp://127.0.0.1:0", &parsed.problem_spec)
        .expect("bind")
        .accept_timeout(Duration::from_secs(60))
        .io_timeout(Duration::from_secs(60));
    let listen = sock.local_addr().expect("bound address");
    let joins = spawn_agents(&listen, parsed.n_workers);
    let r = TrainSession::builder(&suite.problem)
        .schedule_spec(&parsed.schedule_spec)
        .expect("schedule validated at parse")
        .config(parsed.cfg.clone())
        .transport(sock)
        .run();
    for j in joins {
        j.join().expect("agent thread").expect("agent exits cleanly");
    }
    assert!(r.transport_error.is_none(), "solo run failed: {:?}", r.transport_error);
    r
}

struct Daemon {
    addr: String,
    flag: Arc<AtomicBool>,
    join: thread::JoinHandle<anyhow::Result<()>>,
}

fn start_daemon_opts(
    fleet: usize,
    spawn_workers: bool,
    journal: Option<std::path::PathBuf>,
) -> Daemon {
    let mut opts = ServeOptions::new("tcp://127.0.0.1:0");
    opts.fleet = Some(fleet);
    opts.spawn_workers = spawn_workers;
    opts.journal = journal;
    let service = Service::bind(opts).expect("bind daemon");
    let addr = service.local_addr().to_string();
    let flag = service.shutdown_flag();
    let join = thread::spawn(move || service.run());
    Daemon { addr, flag, join }
}

fn start_daemon(fleet: usize) -> Daemon {
    start_daemon_opts(fleet, true, None)
}

impl Daemon {
    fn stop(self) {
        self.flag.store(true, Ordering::SeqCst);
        self.join.join().expect("daemon thread").expect("daemon exits cleanly");
    }
}

fn client(addr: &str) -> ServiceClient {
    ServiceClient::connect(addr, Duration::from_secs(60)).expect("connect to daemon")
}

fn submit(c: &mut ServiceClient, spec: &str) -> u64 {
    match c.submit(spec).expect("submit") {
        ServeFrame::Status(s) => {
            assert_eq!(s.phase, SessionPhase::Queued, "fresh submissions queue");
            s.id
        }
        other => panic!("unexpected submit reply: {other:?}"),
    }
}

/// Attach and collect every streamed record plus the terminal result.
fn attach_collect(c: &mut ServiceClient, id: u64) -> (Vec<RoundRecord>, SessionResult) {
    let mut records = Vec::new();
    let terminal = c
        .attach(id, |f| {
            if let ServeFrame::Metric(m) = f {
                records.push(m.record.clone());
            }
        })
        .expect("attach");
    match terminal {
        ServeFrame::Result(r) => (records, r),
        other => panic!("unexpected terminal frame: {other:?}"),
    }
}

fn daemon_run(addr: &str, spec: &str) -> (Vec<RoundRecord>, SessionResult) {
    let mut c = client(addr);
    let id = submit(&mut c, spec);
    attach_collect(&mut c, id)
}

fn spawn_daemon_run(
    addr: &str,
    spec: &str,
) -> thread::JoinHandle<(Vec<RoundRecord>, SessionResult)> {
    let addr = addr.to_string();
    let spec = spec.to_string();
    thread::spawn(move || daemon_run(&addr, &spec))
}

fn wait_for_phase(c: &mut ServiceClient, id: u64, want: SessionPhase) {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        match c.status(id).expect("status") {
            ServeFrame::Status(s) if s.phase == want => return,
            ServeFrame::Status(_) => {}
            other => panic!("unexpected status reply: {other:?}"),
        }
        assert!(Instant::now() < deadline, "session {id} never reached {want:?}");
        thread::sleep(Duration::from_millis(10));
    }
}

/// Bit-for-bit physics equality between a daemon-run session (streamed
/// records + wire result) and its solo `Socket` reference.
fn assert_daemon_matches_solo(
    solo: &TrainResult,
    records: &[RoundRecord],
    res: &SessionResult,
    tag: &str,
) {
    assert!(res.error.is_none(), "{tag}: {:?}", res.error);
    assert_eq!(res.rounds_run, solo.rounds_run as u64, "{tag}: rounds_run");
    assert_eq!(records.len(), solo.records.len(), "{tag}: record count");
    for (ra, rb) in records.iter().zip(&solo.records) {
        assert_eq!(
            ra.grad_norm_sq.to_bits(),
            rb.grad_norm_sq.to_bits(),
            "{tag} round {}: grad_norm_sq {} vs {}",
            ra.t,
            ra.grad_norm_sq,
            rb.grad_norm_sq
        );
        assert_eq!(ra.g_err.to_bits(), rb.g_err.to_bits(), "{tag} round {}: g_err", ra.t);
        assert_eq!(ra.bits_up_cum, rb.bits_up_cum, "{tag} round {}: bits_up_cum", ra.t);
        assert_eq!(ra.bits_up_max, rb.bits_up_max, "{tag} round {}: bits_up_max", ra.t);
        assert_eq!(ra.bits_down_cum, rb.bits_down_cum, "{tag} round {}: bits_down_cum", ra.t);
        assert_eq!(ra.skipped_frac, rb.skipped_frac, "{tag} round {}: skipped_frac", ra.t);
        assert_eq!(ra.mech_switch, rb.mech_switch, "{tag} round {}: mech_switch", ra.t);
        assert_eq!(ra.loss, rb.loss, "{tag} round {}: loss", ra.t);
    }
    assert_eq!(
        res.final_grad_norm_sq.to_bits(),
        solo.final_grad_norm_sq.to_bits(),
        "{tag}: final_grad_norm_sq"
    );
    assert_eq!(res.converged, solo.converged, "{tag}: converged");
    assert_eq!(res.diverged, solo.diverged, "{tag}: diverged");
    assert_eq!(res.total_bits_up, solo.total_bits_up, "{tag}: total_bits_up");
    assert_eq!(res.total_bits_down, solo.total_bits_down, "{tag}: total_bits_down");
    assert_eq!(res.wire_bytes_up, solo.wire_bytes_up, "{tag}: wire_bytes_up");
    assert_eq!(res.wire_bytes_down, solo.wire_bytes_down, "{tag}: wire_bytes_down");
}

#[test]
fn concurrent_sessions_reproduce_solo_socket_traces() {
    let specs = [spec_ef21(), spec_clag(), spec_switch()];
    let solos: Vec<TrainResult> = specs.iter().map(|s| solo_reference(s)).collect();

    // A fleet big enough for two sessions at once: each pair below
    // runs concurrently, interleaved round-by-round by the scheduler.
    let daemon = start_daemon(2 * N);
    for (i, j) in [(0usize, 1usize), (2, 0)] {
        let ta = spawn_daemon_run(&daemon.addr, &specs[i]);
        let tb = spawn_daemon_run(&daemon.addr, &specs[j]);
        let (recs_a, res_a) = ta.join().expect("client thread");
        let (recs_b, res_b) = tb.join().expect("client thread");
        assert_daemon_matches_solo(&solos[i], &recs_a, &res_a, &specs[i]);
        assert_daemon_matches_solo(&solos[j], &recs_b, &res_b, &specs[j]);
    }
    daemon.stop();

    // The measured-byte contracts (daemon results equal these solo
    // values bit-for-bit, so they hold behind the daemon too).
    let init_bits = (N * 32 * D) as u64;
    let broadcast = |rounds: u64| rounds * (ROUND_PAYLOAD_BYTES as u64 + 4 * D as u64);
    for (spec, solo) in specs.iter().zip(&solos) {
        assert_eq!(
            8 * solo.wire_bytes_up,
            solo.total_bits_up - init_bits,
            "{spec}: every billed uplink bit beyond g⁰ init is a measured wire byte"
        );
    }
    assert_eq!(solos[0].wire_bytes_down, broadcast(solos[0].rounds_run as u64), "{}", specs[0]);
    assert_eq!(solos[1].wire_bytes_down, broadcast(solos[1].rounds_run as u64), "{}", specs[1]);
    assert!(
        solos[2].wire_bytes_down > broadcast(solos[2].rounds_run as u64),
        "{}: the mid-run switch directive is billed on top of broadcasts",
        specs[2]
    );
}

#[test]
fn admission_rejects_are_structured() {
    let daemon = start_daemon(N);
    let mut c = client(&daemon.addr);
    let oversized = format!(
        "problem={};mech=ef21:top3",
        quad_problem_spec(16, D, LAMBDA, NOISE, QSEED)
    );
    let cases: &[(&str, RejectCode)] = &[
        ("rounds=40", RejectCode::BadSpec),
        ("problem=quad:nope;mech=ef21:top3", RejectCode::BadSpec),
        ("problem=logreg:a9a;mech=ef21:top3", RejectCode::UnsupportedProblem),
        (oversized.as_str(), RejectCode::FleetMismatch),
    ];
    for (spec, want) in cases {
        match c.submit(spec).expect("submit") {
            ServeFrame::Reject { code, reason } => {
                assert_eq!(code, *want, "spec '{spec}' → '{reason}'");
                assert!(!reason.is_empty(), "spec '{spec}'");
            }
            other => panic!("spec '{spec}': expected a reject, got {other:?}"),
        }
    }
    // Lookups on an id nobody was granted are structured too.
    for reply in [c.status(404).expect("status"), c.cancel(404).expect("cancel")] {
        match reply {
            ServeFrame::Reject { code, .. } => assert_eq!(code, RejectCode::UnknownSession),
            other => panic!("expected an unknown-session reject, got {other:?}"),
        }
    }
    match c.attach(404, |_| {}).expect("attach") {
        ServeFrame::Reject { code, .. } => assert_eq!(code, RejectCode::UnknownSession),
        other => panic!("expected an unknown-session reject, got {other:?}"),
    }
    daemon.stop();
}

#[test]
fn cancel_mid_run_returns_the_fleet() {
    let daemon = start_daemon(N);
    let mut c = client(&daemon.addr);
    let long =
        format!("problem={};mech=ef21:top3;rounds=1000000;gamma=0.001;seed=13", problem_spec());
    let id = submit(&mut c, &long);
    wait_for_phase(&mut c, id, SessionPhase::Running);
    match c.cancel(id).expect("cancel") {
        ServeFrame::Status(s) => assert_eq!(s.phase, SessionPhase::Cancelled),
        other => panic!("unexpected cancel reply: {other:?}"),
    }
    // Cancelling again is idempotent.
    match c.cancel(id).expect("cancel twice") {
        ServeFrame::Status(s) => assert_eq!(s.phase, SessionPhase::Cancelled),
        other => panic!("unexpected cancel reply: {other:?}"),
    }
    // The granted workers went back to the fleet: a fresh session runs
    // to completion on them, matching its solo trace.
    let solo = solo_reference(&spec_ef21());
    let (records, res) = daemon_run(&daemon.addr, &spec_ef21());
    assert_daemon_matches_solo(&solo, &records, &res, "post-cancel session");
    daemon.stop();
}

#[test]
fn shutdown_drains_running_and_fails_queued() {
    let cp = std::env::temp_dir().join(format!("3pc-serve-drain-{}.bin", std::process::id()));
    let _ = std::fs::remove_file(&cp);
    let daemon = start_daemon(N);
    let running_spec = format!(
        "problem={};mech=ef21:top3;rounds=1000000;gamma=0.001;seed=13;checkpoint={};\
         checkpoint-every=1000000",
        problem_spec(),
        cp.display()
    );
    let mut c1 = client(&daemon.addr);
    let id1 = submit(&mut c1, &running_spec);
    let mut c2 = client(&daemon.addr);
    // The fleet is fully granted to session 1, so this one stays queued.
    let id2 = submit(&mut c2, &spec_ef21());
    let mut c3 = client(&daemon.addr);
    wait_for_phase(&mut c3, id1, SessionPhase::Running);

    let t1 = thread::spawn(move || attach_collect(&mut c1, id1));
    let t2 = thread::spawn(move || attach_collect(&mut c2, id2));
    // Let both attach requests reach the scheduler before draining.
    thread::sleep(Duration::from_millis(200));
    daemon.flag.store(true, Ordering::SeqCst);

    let (records1, res1) = t1.join().expect("attach thread");
    let (records2, res2) = t2.join().expect("attach thread");
    assert_eq!(res1.error.as_deref(), Some("server shutdown"), "running session drained");
    assert!(res1.rounds_run > 0, "session 1 made progress before the drain");
    assert_eq!(records1.len() as u64, res1.rounds_run, "every drained round streamed");
    assert_eq!(res2.error.as_deref(), Some("server shutdown"), "queued session failed");
    assert_eq!(res2.rounds_run, 0);
    assert!(records2.is_empty());
    daemon.stop();

    // The drain wrote the configured checkpoint at the round boundary.
    let written = Checkpoint::load(&cp).expect("drain checkpoint written");
    assert_eq!(written.x.len(), D);
    assert_eq!(written.worker_g.len(), N);
    let _ = std::fs::remove_file(&cp);
}

fn wait_for_rounds(c: &mut ServiceClient, id: u64, min: u64) {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        match c.status(id).expect("status") {
            ServeFrame::Status(s) if s.rounds >= min => return,
            ServeFrame::Status(_) => {}
            other => panic!("unexpected status reply: {other:?}"),
        }
        assert!(Instant::now() < deadline, "session {id} never reached {min} rounds");
        thread::sleep(Duration::from_millis(10));
    }
}

/// The crash-safe daemon: a `--journal`ed daemon stopped mid-run and
/// restarted on the same journal re-admits its queued session and
/// resumes its running one from the drain checkpoint — and the resumed
/// session's terminal result (rounds, final gradient norm, the full
/// billed-bit and measured-byte ledger) equals the undisturbed solo
/// reference's bit for bit, with the resumed round records matching the
/// reference's at every round index. A third daemon on the same journal
/// still knows both terminal results and never reuses their ids.
#[test]
fn journal_restart_resumes_running_and_readmits_queued_sessions() {
    const ROUNDS: usize = 12000;
    let dir = std::env::temp_dir();
    let journal = dir.join(format!("3pc-serve-journal-{}.bin", std::process::id()));
    let ckpt = dir.join(format!("3pc-serve-journal-ckpt-{}.bin", std::process::id()));
    let _ = std::fs::remove_file(&journal);
    let _ = std::fs::remove_file(&ckpt);
    let long_spec = format!(
        "problem={};mech=ef21:top3;rounds={ROUNDS};gamma=0.001;seed=13;checkpoint={};\
         checkpoint-every=500",
        problem_spec(),
        ckpt.display()
    );
    let solo_long = solo_reference(&long_spec);
    let solo_queued = solo_reference(&spec_ef21());

    // Daemon 1: the long session runs (on the whole fleet), the second
    // stays queued; the stop drains mid-run, checkpointing the runner.
    let daemon = start_daemon_opts(N, true, Some(journal.clone()));
    let mut c = client(&daemon.addr);
    let id1 = submit(&mut c, &long_spec);
    let id2 = submit(&mut c, &spec_ef21());
    wait_for_rounds(&mut c, id1, 10);
    drop(c);
    daemon.stop();
    let cp = Checkpoint::load(&ckpt).expect("drain checkpoint written");
    let resume_t = cp.t;
    assert!(resume_t + 1 < ROUNDS, "the drain landed mid-run");

    // Daemon 2, same journal, fresh address, externally-run workers so
    // both attaches are registered before any round can step.
    let daemon2 = start_daemon_opts(N, false, Some(journal.clone()));
    let t1 = {
        let addr = daemon2.addr.to_string();
        thread::spawn(move || {
            let mut c = client(&addr);
            attach_collect(&mut c, id1)
        })
    };
    let t2 = {
        let addr = daemon2.addr.to_string();
        thread::spawn(move || {
            let mut c = client(&addr);
            attach_collect(&mut c, id2)
        })
    };
    // Let both attach requests reach the scheduler before the fleet
    // arrives and rounds start stepping.
    thread::sleep(Duration::from_millis(200));
    let agents = spawn_agents(&daemon2.addr, N);
    let (recs1, res1) = t1.join().expect("attach thread");
    let (recs2, res2) = t2.join().expect("attach thread");

    // The resumed session finished the horizon from the checkpoint:
    // records pick up at resume_t + 1 and match the reference's rounds.
    assert!(res1.error.is_none(), "{:?}", res1.error);
    assert_eq!(res1.rounds_run, ROUNDS as u64, "the round clock is cumulative");
    assert_eq!(recs1.first().map(|r| r.t), Some(resume_t + 1), "resumed, not rerun");
    assert_eq!(recs1.len(), ROUNDS - (resume_t + 1));
    for r in &recs1 {
        let want = &solo_long.records[r.t];
        assert_eq!(want.t, r.t, "reference records every round");
        assert_eq!(r.grad_norm_sq.to_bits(), want.grad_norm_sq.to_bits(), "round {}", r.t);
        assert_eq!(r.g_err.to_bits(), want.g_err.to_bits(), "round {}", r.t);
        assert_eq!(r.bits_up_cum, want.bits_up_cum, "round {}", r.t);
        assert_eq!(r.bits_down_cum, want.bits_down_cum, "round {}", r.t);
    }
    assert_eq!(res1.final_grad_norm_sq.to_bits(), solo_long.final_grad_norm_sq.to_bits());
    assert_eq!(res1.total_bits_up, solo_long.total_bits_up, "billed uplink continues");
    assert_eq!(res1.total_bits_down, solo_long.total_bits_down, "billed downlink continues");
    assert_eq!(res1.wire_bytes_up, solo_long.wire_bytes_up, "recovery traffic is unmeasured");
    assert_eq!(res1.wire_bytes_down, solo_long.wire_bytes_down);

    // The re-admitted queued session ran fresh and in full.
    assert_daemon_matches_solo(&solo_queued, &recs2, &res2, "re-admitted queued session");
    daemon2.stop();
    for a in agents {
        a.join().expect("agent thread").expect("agent exits cleanly");
    }

    // Daemon 3, same journal: both results survive, ids are not reused.
    let daemon3 = start_daemon_opts(N, false, Some(journal.clone()));
    let mut c3 = client(&daemon3.addr);
    for (id, rounds) in [(id1, ROUNDS as u64), (id2, 40u64)] {
        match c3.status(id).expect("status") {
            ServeFrame::Status(s) => {
                assert_eq!(s.phase, SessionPhase::Done, "session {id}");
                assert_eq!(s.rounds, rounds, "session {id}");
            }
            other => panic!("unexpected status reply: {other:?}"),
        }
    }
    let id3 = submit(&mut c3, &spec_ef21());
    assert!(id3 > id2, "terminal ids are never reused after a replay");
    drop(c3);
    daemon3.stop();
    let _ = std::fs::remove_file(&journal);
    let _ = std::fs::remove_file(&ckpt);
}
