//! Acceptance suite for the socket transport: loopback TCP and UDS
//! sessions with real worker agents must reproduce the `Framed` and
//! `InProcess` traces bit-for-bit for every mechanism the spec grammar
//! can produce, with measured byte accounting agreeing across
//! transports; and every hostile condition — malformed frames, a
//! session-contract violation, a peer dying mid-round, workers that
//! never connect — must surface as `TrainResult::transport_error`,
//! never a panic.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::thread;
use std::time::Duration;

use threepc::coordinator::protocol::{
    decode_downlink, encode_round_reply, encode_uplink, encode_worker_hello, DownlinkFrame,
    ROUND_PAYLOAD_BYTES,
};
use threepc::coordinator::socket::quad_problem_spec;
use threepc::coordinator::{
    encode_mech_switch, run_worker_agent, AgentConfig, Framed, InProcess, InitPolicy, MechSwitch,
    ResumeState, Socket, TrainConfig, TrainResult, TrainSession, TransportError, UplinkMsg,
};
use threepc::mechanisms::{parse_mechanism, ReplaceWire, Update};
use threepc::problems::quadratic;

const N: usize = 4;
const D: usize = 30;
const LAMBDA: f64 = 1e-2;
const NOISE: f64 = 0.5;
const QSEED: u64 = 21;

/// Every spec `parse_all_specs` pins down.
const ALL_SPECS: [&str; 11] = [
    "gd",
    "dcgd:top3",
    "ef21:top3",
    "lag:2.0",
    "clag:top3:2.0",
    "v1:top3",
    "v2:rand3:top3",
    "v3:ef21:top3;top2",
    "v4:top3:top2",
    "v5:0.3:top3",
    "marina:0.3:rand3",
];

fn suite() -> quadratic::QuadSuite {
    quadratic::generate(N, D, LAMBDA, NOISE, QSEED)
}

fn problem_spec() -> String {
    quad_problem_spec(N, D, LAMBDA, NOISE, QSEED)
}

fn cfg(rounds: usize) -> TrainConfig {
    // threads = 1 pins the in-process f64 fold order; the serializing
    // transports fold in worker order by construction.
    TrainConfig { gamma: 0.02, max_rounds: rounds, threads: 1, seed: 13, ..TrainConfig::default() }
}

fn bind_socket(addr: &str) -> Socket {
    Socket::bind(addr, &problem_spec())
        .expect("bind")
        .accept_timeout(Duration::from_secs(60))
        .io_timeout(Duration::from_secs(60))
}

/// A fresh, short, unique uds path (parallel tests must not collide).
fn uds_addr() -> String {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    let id = NEXT.fetch_add(1, Ordering::Relaxed);
    let path = std::env::temp_dir().join(format!(
        "3pc-{}-{}.sock",
        std::process::id(),
        id
    ));
    format!("uds://{}", path.display())
}

fn spawn_agents(addr: &str, n: usize) -> Vec<thread::JoinHandle<anyhow::Result<()>>> {
    (0..n)
        .map(|_| {
            let a = addr.to_string();
            thread::spawn(move || run_worker_agent(&a, &AgentConfig::default()))
        })
        .collect()
}

fn join_agents(joins: Vec<thread::JoinHandle<anyhow::Result<()>>>) {
    for j in joins {
        j.join().expect("agent thread").expect("agent exits cleanly");
    }
}

fn run_inproc(s: &quadratic::QuadSuite, spec: &str, c: &TrainConfig) -> TrainResult {
    TrainSession::builder(&s.problem)
        .mechanism_spec(spec)
        .unwrap()
        .config(c.clone())
        .transport(InProcess::new(1))
        .run()
}

fn run_framed(s: &quadratic::QuadSuite, spec: &str, c: &TrainConfig) -> TrainResult {
    TrainSession::builder(&s.problem)
        .mechanism_spec(spec)
        .unwrap()
        .config(c.clone())
        .transport(Framed::default())
        .run()
}

fn run_socket(s: &quadratic::QuadSuite, spec: &str, c: &TrainConfig, addr: &str) -> TrainResult {
    let sock = bind_socket(addr);
    let listen = sock.local_addr().expect("bound address");
    let joins = spawn_agents(&listen, N);
    let r = TrainSession::builder(&s.problem)
        .mechanism_spec(spec)
        .unwrap()
        .config(c.clone())
        .transport(sock)
        .run();
    join_agents(joins);
    r
}

/// Bit-for-bit physics equality (everything transport-independent).
fn assert_trace_eq(a: &TrainResult, b: &TrainResult, tag: &str) {
    assert_eq!(a.rounds_run, b.rounds_run, "{tag}: rounds_run");
    assert_eq!(a.records.len(), b.records.len(), "{tag}: record count");
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(
            ra.grad_norm_sq.to_bits(),
            rb.grad_norm_sq.to_bits(),
            "{tag} round {}: grad_norm_sq {} vs {}",
            ra.t,
            ra.grad_norm_sq,
            rb.grad_norm_sq
        );
        assert_eq!(ra.g_err.to_bits(), rb.g_err.to_bits(), "{tag} round {}: g_err", ra.t);
        assert_eq!(ra.skipped_frac, rb.skipped_frac, "{tag} round {}: skipped_frac", ra.t);
        assert_eq!(ra.bits_down_cum, rb.bits_down_cum, "{tag} round {}: bits_down_cum", ra.t);
        assert_eq!(ra.mech_switch, rb.mech_switch, "{tag} round {}: mech_switch", ra.t);
        assert_eq!(ra.loss, rb.loss, "{tag} round {}: loss", ra.t);
    }
    for (i, (xa, xb)) in a.final_x.iter().zip(&b.final_x).enumerate() {
        assert_eq!(xa.to_bits(), xb.to_bits(), "{tag}: final_x[{i}]");
    }
}

/// The measured-byte contract a socket run must satisfy against its
/// `Framed` twin: identical uplink frames (so identical measured
/// uplink bytes and billed bits), and downlink = Framed's billable
/// directives plus the per-round broadcast payload.
fn assert_socket_accounting(framed: &TrainResult, sock: &TrainResult, init_bits: u64, tag: &str) {
    assert!(sock.transport_error.is_none(), "{tag}: {:?}", sock.transport_error);
    for (rb, rc) in framed.records.iter().zip(&sock.records) {
        assert_eq!(rb.bits_up_cum, rc.bits_up_cum, "{tag} round {}: bits_up_cum", rb.t);
        assert_eq!(rb.bits_up_max, rc.bits_up_max, "{tag} round {}: bits_up_max", rb.t);
    }
    assert_eq!(framed.wire_bytes_up, sock.wire_bytes_up, "{tag}: measured uplink bytes");
    assert_eq!(
        8 * sock.wire_bytes_up,
        sock.total_bits_up - init_bits,
        "{tag}: every billed uplink bit beyond g⁰ init is a measured wire byte"
    );
    let broadcast = (sock.rounds_run as u64) * (ROUND_PAYLOAD_BYTES as u64 + 4 * D as u64);
    assert_eq!(
        sock.wire_bytes_down,
        framed.wire_bytes_down + broadcast,
        "{tag}: downlink = framed's directives + round broadcasts"
    );
}

#[test]
fn socket_tcp_matches_framed_and_inprocess_for_every_mechanism() {
    let s = suite();
    let c = cfg(25);
    let init_bits = (N * 32 * D) as u64;
    for spec in ALL_SPECS {
        let a = run_inproc(&s, spec, &c);
        let b = run_framed(&s, spec, &c);
        let sock = run_socket(&s, spec, &c, "tcp://127.0.0.1:0");
        assert_trace_eq(&a, &sock, &format!("tcp {spec} (vs inprocess)"));
        assert_trace_eq(&b, &sock, &format!("tcp {spec} (vs framed)"));
        assert_socket_accounting(&b, &sock, init_bits, &format!("tcp {spec}"));
    }
}

#[cfg(unix)]
#[test]
fn socket_uds_matches_framed_and_inprocess_for_every_mechanism() {
    let s = suite();
    let c = cfg(25);
    let init_bits = (N * 32 * D) as u64;
    for spec in ALL_SPECS {
        let b = run_framed(&s, spec, &c);
        let sock = run_socket(&s, spec, &c, &uds_addr());
        assert_trace_eq(&b, &sock, &format!("uds {spec}"));
        assert_socket_accounting(&b, &sock, init_bits, &format!("uds {spec}"));
    }
}

#[test]
fn schedule_switch_crosses_the_socket() {
    let s = suite();
    let sched = "clag:top3:2.0@0..8,ef21:top3@8..";
    let c = cfg(16);
    let a = TrainSession::builder(&s.problem)
        .schedule_spec(sched)
        .unwrap()
        .config(c.clone())
        .transport(InProcess::new(1))
        .run();
    let sock = bind_socket("tcp://127.0.0.1:0");
    let listen = sock.local_addr().unwrap();
    let joins = spawn_agents(&listen, N);
    let r = TrainSession::builder(&s.problem)
        .schedule_spec(sched)
        .unwrap()
        .config(c)
        .transport(sock)
        .run();
    // Agents exiting cleanly proves they parsed and installed the
    // switched mechanism from the directive's spec.
    join_agents(joins);
    assert_trace_eq(&a, &r, "piecewise over socket");
    assert_eq!(r.mech_switches(), a.mech_switches());
    let ef = parse_mechanism("ef21:top3").unwrap();
    let frame =
        encode_mech_switch(&MechSwitch { round: 8, mech: ef.name(), spec: ef.spec() }).unwrap();
    let broadcast = (r.rounds_run as u64) * (ROUND_PAYLOAD_BYTES as u64 + 4 * D as u64);
    assert_eq!(r.wire_bytes_down, broadcast + frame.len() as u64);
}

#[test]
fn loss_sidecar_matches_framed() {
    let s = suite();
    let mut c = cfg(12);
    c.eval_loss_every = 3;
    let b = run_framed(&s, "ef21:top3", &c);
    let sock = run_socket(&s, "ef21:top3", &c, "tcp://127.0.0.1:0");
    assert_trace_eq(&b, &sock, "loss eval");
    assert!(sock.records.iter().any(|r| r.loss.is_some()), "loss rounds present");
}

#[test]
fn natural_value_coding_agrees_with_framed_natural() {
    let s = suite();
    let c = cfg(15);
    let b = TrainSession::builder(&s.problem)
        .mechanism_spec("ef21:top3")
        .unwrap()
        .config(c.clone())
        .transport(Framed::natural())
        .run();
    let sock = Socket::bind("tcp://127.0.0.1:0", &problem_spec())
        .unwrap()
        .accept_timeout(Duration::from_secs(60))
        .natural();
    let listen = sock.local_addr().unwrap();
    let joins = spawn_agents(&listen, N);
    let r = TrainSession::builder(&s.problem)
        .mechanism_spec("ef21:top3")
        .unwrap()
        .config(c)
        .transport(sock)
        .run();
    join_agents(joins);
    assert_trace_eq(&b, &r, "natural coding");
    assert_eq!(b.wire_bytes_up, r.wire_bytes_up, "natural frames agree byte-for-byte");
}

#[test]
fn zero_init_crosses_the_wire() {
    let s = suite();
    let mut c = cfg(10);
    c.init = InitPolicy::Zero;
    let b = run_framed(&s, "clag:top3:2.0", &c);
    let sock = run_socket(&s, "clag:top3:2.0", &c, "tcp://127.0.0.1:0");
    assert_trace_eq(&b, &sock, "zero init");
    // Zero init bills nothing, so all billed bits are measured bytes.
    assert_eq!(8 * sock.wire_bytes_up, sock.total_bits_up);
}

/// A deliberately slow worker ([`AgentConfig::reply_delay`]) makes
/// replies land out of id order: three agents answer each round
/// immediately while one sits on every reply for ~40 ms (connection
/// order assigns ids, so the delay lands on *some* worker — which one
/// doesn't matter). The leader's readiness-driven drain reads whatever
/// arrives first but decodes, validates and folds in strict id order,
/// so the trace and the byte accounting must stay bit-for-bit equal to
/// both the all-fast socket run and the `Framed` reference.
#[test]
fn slow_worker_replies_do_not_perturb_trace_or_accounting() {
    let s = suite();
    let c = cfg(8);
    let b = run_framed(&s, "ef21:top3", &c);
    let fast = run_socket(&s, "ef21:top3", &c, "tcp://127.0.0.1:0");

    let sock = bind_socket("tcp://127.0.0.1:0");
    let listen = sock.local_addr().unwrap();
    let joins: Vec<_> = (0..N)
        .map(|i| {
            let a = listen.clone();
            thread::spawn(move || {
                let mut acfg = AgentConfig::default();
                if i == 0 {
                    acfg.reply_delay = Duration::from_millis(40);
                }
                run_worker_agent(&a, &acfg)
            })
        })
        .collect();
    let slow = TrainSession::builder(&s.problem)
        .mechanism_spec("ef21:top3")
        .unwrap()
        .config(c)
        .transport(sock)
        .run();
    join_agents(joins);

    let init_bits = (N * 32 * D) as u64;
    assert_trace_eq(&b, &fast, "slow-worker control (framed vs fast socket)");
    assert_trace_eq(&b, &slow, "slow worker (framed vs delayed socket)");
    assert_socket_accounting(&b, &slow, init_bits, "slow worker");
}

// ---------------------------------------------------------------------
// Hostile peers. A rogue client speaks just enough of the protocol to
// reach the round loop, then misbehaves; the leader must end the run
// with a descriptive TransportError, never a panic.
// ---------------------------------------------------------------------

enum Rogue {
    /// Replies to the first round with an undecodable frame.
    Garbage,
    /// Replies with a well-formed frame whose update carries the wrong
    /// dimension (the link-layer contract check).
    WrongDim,
    /// Drops the connection after reading the first round frame.
    Disconnect,
}

fn write_raw(s: &mut TcpStream, body: &[u8]) {
    s.write_all(&(body.len() as u32).to_le_bytes()).unwrap();
    s.write_all(body).unwrap();
    s.flush().unwrap();
}

fn read_raw(s: &mut TcpStream) -> Option<Vec<u8>> {
    let mut lb = [0u8; 4];
    s.read_exact(&mut lb).ok()?;
    let mut b = vec![0u8; u32::from_le_bytes(lb) as usize];
    s.read_exact(&mut b).ok()?;
    Some(b)
}

fn spawn_rogue(addr: String, mode: Rogue) -> thread::JoinHandle<()> {
    thread::spawn(move || {
        let hostport = addr.strip_prefix("tcp://").expect("tcp address").to_string();
        let mut s = TcpStream::connect(&hostport).expect("rogue connect");
        write_raw(&mut s, &encode_worker_hello());
        let hello = match decode_downlink(&read_raw(&mut s).expect("hello")).expect("hello frame")
        {
            DownlinkFrame::Hello(h) => h,
            other => panic!("expected hello, got {other:?}"),
        };
        // Await the first round broadcast, then misbehave.
        let _ = read_raw(&mut s).expect("round frame");
        match mode {
            Rogue::Disconnect => drop(s),
            Rogue::Garbage => {
                write_raw(&mut s, &[0xe2, 0x00, 0x03]);
                let _ = read_raw(&mut s); // leader shutdown / close
            }
            Rogue::WrongDim => {
                let d = hello.dim as usize;
                let up = encode_uplink(&UplinkMsg {
                    worker_id: hello.worker_id as usize,
                    update: Update::Replace {
                        g: vec![0.0; d + 1],
                        bits: 32 * (d as u64 + 1),
                        wire: ReplaceWire::Dense,
                    },
                    g_err: 0.0,
                });
                let grad = vec![0.0f32; d];
                let mut body = Vec::new();
                // Echo round 0 — the round this reply answers — so the
                // dimension check is what fires, not the stale-reply one.
                encode_round_reply(0, &up, &grad, None, &mut body);
                write_raw(&mut s, &body);
                let _ = read_raw(&mut s);
            }
        }
    })
}

/// Run a session against N-1 honest agents and one rogue.
fn run_with_rogue(mode: Rogue) -> TrainResult {
    let s = suite();
    // A short io timeout: a dead slot now waits for a rejoin before the
    // round can finish, and no replacement is coming in these scenarios.
    let sock = bind_socket("tcp://127.0.0.1:0").io_timeout(Duration::from_secs(2));
    let listen = sock.local_addr().unwrap();
    let rogue = spawn_rogue(listen.clone(), mode);
    let agents = spawn_agents(&listen, N - 1);
    let r = TrainSession::builder(&s.problem)
        .mechanism_spec("ef21:top3")
        .unwrap()
        .config(cfg(10))
        .transport(sock)
        .run();
    let _ = rogue.join();
    // Honest agents end via the leader's shutdown frame or the dropped
    // connection; either way they must not hang.
    for a in agents {
        let _ = a.join().expect("agent thread");
    }
    r
}

#[test]
fn malformed_reply_surfaces_as_protocol_error() {
    let r = run_with_rogue(Rogue::Garbage);
    match &r.transport_error {
        Some(TransportError::Protocol(m)) => {
            assert!(m.contains("reply"), "unexpected message: {m}")
        }
        other => panic!("expected a protocol error, got {other:?}"),
    }
    assert_eq!(r.rounds_run, 0, "the failed round must not count");
    assert!(r.records.is_empty());
}

#[test]
fn wrong_dimension_update_surfaces_as_protocol_error() {
    let r = run_with_rogue(Rogue::WrongDim);
    match &r.transport_error {
        Some(TransportError::Protocol(m)) => {
            assert!(m.contains("dimension"), "unexpected message: {m}")
        }
        other => panic!("expected a protocol error, got {other:?}"),
    }
}

#[test]
fn mid_round_disconnect_surfaces_as_transport_error() {
    let r = run_with_rogue(Rogue::Disconnect);
    match &r.transport_error {
        Some(TransportError::Disconnected(_)) | Some(TransportError::Io(_)) => {}
        other => panic!("expected a disconnect/io error, got {other:?}"),
    }
}

#[test]
fn missing_workers_surface_as_connect_error() {
    let s = suite();
    let sock = Socket::bind("tcp://127.0.0.1:0", &problem_spec())
        .unwrap()
        .accept_timeout(Duration::from_millis(100));
    let r = TrainSession::builder(&s.problem)
        .mechanism_spec("gd")
        .unwrap()
        .config(cfg(5))
        .transport(sock)
        .run();
    match &r.transport_error {
        Some(TransportError::Io(m)) => assert!(m.contains("accept timed out"), "{m}"),
        other => panic!("expected an accept timeout, got {other:?}"),
    }
    assert_eq!(r.rounds_run, 0);
    assert!(r.records.is_empty());
}

/// A socket session resumed from a checkpoint reproduces the
/// uninterrupted reference trace bit-for-bit: the restarted leader
/// re-binds, installs the fresh agents through resync frames (no
/// connect-time hello), and continues the round clock and the bit/byte
/// ledger from the checkpoint — so the cumulative totals equal the
/// undisturbed run's, with the recovery traffic neither billed nor
/// measured.
#[cfg(unix)]
#[test]
fn socket_resume_reproduces_the_reference_trace_and_ledger() {
    use threepc::coordinator::{Checkpoint, CheckpointObserver};
    let s = suite();
    let reference = run_socket(&s, "ef21:top3", &cfg(12), &uds_addr());
    assert!(reference.transport_error.is_none(), "{:?}", reference.transport_error);

    // The "killed" leader: 8 rounds, checkpointing at t = 7.
    let path =
        std::env::temp_dir().join(format!("3pc-wire-resume-{}.ckpt", std::process::id()));
    let sock = bind_socket(&uds_addr());
    let listen = sock.local_addr().expect("bound address");
    let joins = spawn_agents(&listen, N);
    let killed = TrainSession::builder(&s.problem)
        .mechanism_spec("ef21:top3")
        .unwrap()
        .config(cfg(8))
        .observer(CheckpointObserver::new(7, path.clone()))
        .transport(sock)
        .run();
    join_agents(joins);
    assert!(killed.transport_error.is_none(), "{:?}", killed.transport_error);
    let cp = Checkpoint::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(cp.t, 7, "last committed round");

    // The restarted leader finishes the horizon with a fresh fleet.
    let sock = bind_socket(&uds_addr());
    let listen = sock.local_addr().expect("bound address");
    let joins = spawn_agents(&listen, N);
    let resumed = TrainSession::resume(&s.problem, &cp)
        .unwrap()
        .mechanism_spec("ef21:top3")
        .unwrap()
        .config(cfg(12))
        .transport(sock)
        .run();
    join_agents(joins);
    assert!(resumed.transport_error.is_none(), "{:?}", resumed.transport_error);

    assert_eq!(resumed.rounds_run, reference.rounds_run, "the round clock is cumulative");
    let tail: Vec<_> = reference.records.iter().filter(|r| r.t >= 8).collect();
    assert_eq!(resumed.records.len(), tail.len());
    for (rr, tr) in resumed.records.iter().zip(&tail) {
        assert_eq!(rr.t, tr.t);
        assert_eq!(rr.grad_norm_sq, tr.grad_norm_sq, "round {}", rr.t);
        assert_eq!(rr.g_err, tr.g_err, "round {}", rr.t);
        assert_eq!(rr.bits_up_cum, tr.bits_up_cum, "round {}", rr.t);
        assert_eq!(rr.bits_down_cum, tr.bits_down_cum, "round {}", rr.t);
    }
    assert_eq!(resumed.final_x, reference.final_x);
    assert_eq!(resumed.total_bits_up, reference.total_bits_up);
    assert_eq!(resumed.total_bits_down, reference.total_bits_down);
    assert_eq!(resumed.wire_bytes_up, reference.wire_bytes_up);
    assert_eq!(resumed.wire_bytes_down, reference.wire_bytes_down);
}

/// Resume state whose shape does not match the session is rejected at
/// connect time, before any agent traffic — a mismatched checkpoint
/// must never silently desynchronise leader mirrors and agents.
#[test]
fn socket_resume_rejects_a_mismatched_state() {
    let s = suite();
    let rs = ResumeState {
        t: 3,
        grad_norm_sq: 1.0,
        x: s.problem.x0.clone(),
        g_sum: vec![0.0; D],
        worker_g: (0..N + 1).map(|_| vec![0.0f32; D]).collect(),
        worker_bits: vec![0; N + 1],
        bits_down: 0,
        wire_bytes_up: 0,
        wire_bytes_down: 0,
    };
    let mut c = cfg(5);
    c.init = InitPolicy::FromState(std::sync::Arc::new(rs));
    let sock = Socket::bind("tcp://127.0.0.1:0", &problem_spec()).unwrap();
    let r = TrainSession::builder(&s.problem)
        .mechanism_spec("gd")
        .unwrap()
        .config(c)
        .transport(sock)
        .run();
    match &r.transport_error {
        Some(TransportError::Protocol(m)) => assert!(m.contains("resume"), "{m}"),
        other => panic!("expected a protocol error, got {other:?}"),
    }
}
