//! Decoder-robustness corpus fuzz: every valid wire frame the system
//! can produce is truncated at every byte offset and bit-flipped at
//! every bit position, and every decoder must come back with `Ok` or
//! `Err` — never a panic — while allocating no more than a small
//! multiple of the frame's own length (a hostile length field must
//! fail its bounds check *before* any allocation is sized from it).
//!
//! The corpus covers the uplink codec (all `parse_all_specs`
//! mechanisms, both value codings, and frames produced by the fused
//! compress→encode fast path), the standalone `CVec` codec, the
//! `MechSwitch` directive, the socket transport's downlink vocabulary
//! (session hello, round broadcast, shutdown), the round reply, and
//! the checkpoint file format.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use threepc::compressors::{CVec, Ctx, CtxInfo, WireValueCoding};
use threepc::coordinator::protocol::{
    assemble_increment_uplink, decode_client_frame, decode_downlink, decode_mech_switch,
    decode_resync, decode_serve_frame, decode_worker_hello, encode_client_frame,
    encode_mech_switch, encode_resync, encode_round_reply, encode_round_start,
    encode_serve_frame, encode_session_hello, encode_uplink_with, encode_worker_hello,
    split_round_reply, ResyncFrame, SessionHello, DOWN_SESSION_END, DOWN_SHUTDOWN, DOWN_SWITCH,
};
use threepc::coordinator::{
    decode_uplink, Checkpoint, ClientFrame, MechSwitch, MetricUpdate, RejectCode, RoundRecord,
    ServeFrame, SessionPhase, SessionResult, SessionStatus, UplinkMsg,
};
use threepc::mechanisms::{parse_mechanism, MechWorker, Update};
use threepc::util::rng::Pcg64;

/// Byte-accounting global allocator (thread-local, like the
/// `alloc_steady` counter): records how many bytes each decode attempt
/// *requests*, so an attempted 16 GiB `Vec::with_capacity` from a
/// hostile dim is caught even on machines where it would succeed.
struct ByteCountingAlloc;

thread_local! {
    static BYTES: Cell<u64> = const { Cell::new(0) };
}

#[inline]
fn bump(n: usize) {
    BYTES.with(|c| c.set(c.get() + n as u64));
}

unsafe impl GlobalAlloc for ByteCountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump(layout.size());
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        bump(layout.size());
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        bump(new_size);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: ByteCountingAlloc = ByteCountingAlloc;

fn bytes_during<F: FnOnce()>(f: F) -> u64 {
    let before = BYTES.with(|c| c.get());
    f();
    BYTES.with(|c| c.get()) - before
}

/// The frame-implied allocation bound. Decoded payloads expand their
/// wire form by small constant factors (9-bit naturals → f32 is ×3.6,
/// bit-packed indices → u32 is ≤ ×32 at 1-bit indices); 64× plus slack
/// for error strings and `Vec` rounding covers every legitimate decode
/// while still failing loudly on an unchecked hostile length.
fn alloc_bound(frame_len: usize) -> u64 {
    64 * frame_len as u64 + 4096
}

/// Run `decode` over the frame, asserting only that it neither panics
/// nor allocates beyond the frame-implied bound (`Err` is the expected
/// outcome for most mutations; a lucky bit flip may still be valid).
fn check(buf: &[u8], decode: &dyn Fn(&[u8])) {
    let used = bytes_during(|| decode(buf));
    let bound = alloc_bound(buf.len());
    assert!(
        used <= bound,
        "decoding a {}-byte frame allocated {used} bytes (bound {bound})",
        buf.len()
    );
}

/// Truncate at every offset and flip every bit of every byte.
fn fuzz_decoder(buf: &[u8], decode: &dyn Fn(&[u8])) {
    for cut in 0..buf.len() {
        check(&buf[..cut], decode);
    }
    let mut work = buf.to_vec();
    for i in 0..work.len() {
        for bit in 0..8 {
            work[i] ^= 1 << bit;
            check(&work, decode);
            work[i] ^= 1 << bit;
        }
    }
}

const ALL_SPECS: [&str; 11] = [
    "gd",
    "dcgd:top4",
    "ef21:top4",
    "lag:4.0",
    "clag:top4:2.0",
    "v1:top4",
    "v2:rand4:top4",
    "v3:ef21:top4;top2",
    "v4:top4:top2",
    "v5:0.25:top4",
    "marina:0.25:rand4",
];

/// Drive every mechanism for a few rounds and collect its encoded
/// uplink frames under both value codings.
fn uplink_corpus() -> Vec<Vec<u8>> {
    let d = 24usize;
    let n = 4usize;
    let mut corpus = Vec::new();
    for spec in ALL_SPECS {
        let map = parse_mechanism(spec).unwrap();
        let mut meta = Pcg64::seed(0xf022 ^ spec.len() as u64);
        let g0: Vec<f32> = (0..d).map(|_| meta.normal() as f32).collect();
        let grad0: Vec<f32> = (0..d).map(|_| meta.normal() as f32).collect();
        let mut worker = MechWorker::new(map, g0, grad0);
        let mut rng = Pcg64::new(11, 0x77);
        let info = CtxInfo { dim: d, n_workers: n, worker_id: 1 };
        for t in 0..6u64 {
            let grad: Vec<f32> = (0..d).map(|_| meta.normal() as f32).collect();
            let mut ctx = Ctx::new(info, &mut rng, t);
            let (update, g_err) = worker.round(&grad, &mut ctx);
            let msg = UplinkMsg { worker_id: 1, update, g_err };
            for coding in [WireValueCoding::RawF32, WireValueCoding::Natural] {
                corpus.push(encode_uplink_with(&msg, coding));
            }
        }
    }
    corpus
}

#[test]
fn uplink_frames_survive_truncation_and_bit_flips() {
    let corpus = uplink_corpus();
    assert!(corpus.len() > 100, "corpus too small: {}", corpus.len());
    let decode: &dyn Fn(&[u8]) = &|b| {
        let _ = decode_uplink(b);
    };
    for frame in &corpus {
        // Corpus sanity: the unmutated frame decodes.
        assert!(decode_uplink(frame).is_ok());
        fuzz_decoder(frame, decode);
    }
}

/// Uplink frames produced by the fused compress→encode fast path
/// (`Ctx::with_wire` + `assemble_increment_uplink`, the route the
/// socket agents and the framed transport take for EF21-over-Top-K)
/// are byte-identical to the generic encoder's output and survive the
/// same truncation/bit-flip battery.
#[test]
fn fused_encoder_uplink_frames_survive_truncation_and_bit_flips() {
    let d = 24usize;
    let n = 4usize;
    let mut corpus = Vec::new();
    // top4: the sparse gather override; top24 = d: the dense k==d
    // branch of the override; top1: the minimal frame.
    for spec in ["ef21:top1", "ef21:top4", "ef21:top24"] {
        let map = parse_mechanism(spec).unwrap();
        let mut meta = Pcg64::seed(0xfa5e ^ spec.len() as u64);
        let g0: Vec<f32> = (0..d).map(|_| meta.normal() as f32).collect();
        let grad0: Vec<f32> = (0..d).map(|_| meta.normal() as f32).collect();
        let mut worker = MechWorker::new(map, g0, grad0);
        let mut rng = Pcg64::new(13, 0x99);
        let info = CtxInfo { dim: d, n_workers: n, worker_id: 2 };
        let mut wire = Vec::new();
        let mut no_acc = Vec::new();
        for t in 0..6u64 {
            let grad: Vec<f32> = (0..d).map(|_| meta.normal() as f32).collect();
            for coding in [WireValueCoding::RawF32, WireValueCoding::Natural] {
                wire.clear();
                let mut ctx = Ctx::new(info, &mut rng, t).with_wire(coding, &mut wire);
                let g_err = worker.round_acc(&grad, &mut ctx, &mut no_acc);
                drop(ctx);
                let Update::Increment { inc, .. } = worker.last_update() else {
                    panic!("{spec} round {t}: expected an Increment update");
                };
                assert!(!wire.is_empty(), "{spec} round {t}: mechanism did not fuse");
                assert_eq!(
                    wire.len(),
                    inc.encoded_len_with(coding),
                    "{spec} round {t} {coding:?}: fused payload length"
                );
                let mut frame = Vec::new();
                assemble_increment_uplink(2, g_err, &wire, &mut frame);
                let msg =
                    UplinkMsg { worker_id: 2, update: worker.last_update().clone(), g_err };
                assert_eq!(
                    frame,
                    encode_uplink_with(&msg, coding),
                    "{spec} round {t} {coding:?}: fused frame must match the generic encoder"
                );
                corpus.push(frame);
            }
        }
    }
    assert!(corpus.len() >= 36, "corpus too small: {}", corpus.len());
    let decode: &dyn Fn(&[u8]) = &|b| {
        let _ = decode_uplink(b);
    };
    for frame in &corpus {
        assert!(decode_uplink(frame).is_ok());
        fuzz_decoder(frame, decode);
    }
}

#[test]
fn cvec_frames_survive_truncation_and_bit_flips() {
    let cases = [
        CVec::Zero { dim: 17 },
        CVec::Dense(vec![1.5, -2.25, 0.0, f32::MIN_POSITIVE, 8.0]),
        CVec::Sparse { dim: 1000, idx: vec![0, 7, 999, 500], val: vec![1.0, -0.5, 3.25, 2.0] },
        // Natural-codable values (tags 3/4 under natural coding).
        CVec::Dense(vec![1.0, -2.0, 0.25, 0.0, 8.0]),
        CVec::Sparse { dim: 1000, idx: vec![1, 10, 999], val: vec![0.5, -4.0, 64.0] },
    ];
    let decode: &dyn Fn(&[u8]) = &|b| {
        let _ = CVec::decode(b, &mut 0);
    };
    for c in &cases {
        for coding in [WireValueCoding::RawF32, WireValueCoding::Natural] {
            let mut buf = Vec::new();
            c.encode_with(coding, &mut buf);
            assert!(CVec::decode(&buf, &mut 0).is_ok());
            fuzz_decoder(&buf, decode);
        }
    }
}

#[test]
fn downlink_frames_survive_truncation_and_bit_flips() {
    let hello = encode_session_hello(&SessionHello {
        worker_id: 2,
        n_workers: 6,
        dim: 30,
        seed: 21,
        zero_init: false,
        value_coding: WireValueCoding::Natural,
        mech_spec: "clag:top4:2.0".into(),
        problem_spec: "quad:6:30:0.01:0.5:21".into(),
    })
    .unwrap();
    let mut round = Vec::new();
    let x: Vec<f32> = (0..30).map(|i| i as f32 * 0.25 - 3.0).collect();
    encode_round_start(9, 0xfeed_f00d, true, &x, &mut round);
    let switch = {
        let inner = encode_mech_switch(&MechSwitch {
            round: 15,
            mech: "EF21(Top-4)".into(),
            spec: "ef21:top4".into(),
        })
        .unwrap();
        let mut body = vec![DOWN_SWITCH];
        body.extend_from_slice(&inner);
        body
    };
    let shutdown = vec![DOWN_SHUTDOWN];
    let session_end = vec![DOWN_SESSION_END];
    let decode: &dyn Fn(&[u8]) = &|b| {
        let _ = decode_downlink(b);
    };
    for frame in [&hello, &round, &switch, &shutdown, &session_end] {
        assert!(decode_downlink(frame).is_ok());
        fuzz_decoder(frame, decode);
    }
    // The tagless control frames decode to their variants exactly and
    // reject any body bytes.
    use threepc::coordinator::protocol::DownlinkFrame;
    assert_eq!(decode_downlink(&shutdown).unwrap(), DownlinkFrame::Shutdown);
    assert_eq!(decode_downlink(&session_end).unwrap(), DownlinkFrame::SessionEnd);
    assert!(decode_downlink(&[DOWN_SHUTDOWN, 0]).is_err());
    assert!(decode_downlink(&[DOWN_SESSION_END, 0]).is_err());
}

/// The rejoin vocabulary: the RESYNC downlink (embedded hello + round
/// directive + `(x, g_i)` mirrors) must survive the same battery, both
/// through the dedicated decoder and through the agent's downlink
/// dispatch. The embedded length fields and the hello-carried dimension
/// are the attack surface — a hostile `dim` must fail the body-length
/// check before it sizes an allocation.
#[test]
fn resync_frames_survive_truncation_and_bit_flips() {
    let d = 30usize;
    let frame = {
        let r = ResyncFrame {
            hello: SessionHello {
                worker_id: 3,
                n_workers: 6,
                dim: d as u32,
                seed: 21,
                zero_init: false,
                value_coding: WireValueCoding::Natural,
                mech_spec: "ef21:top4".into(),
                problem_spec: "quad:6:30:0.01:0.5:21".into(),
            },
            t: 17,
            round_seed: 0xdead_beef,
            eval_loss: true,
            x: (0..d).map(|i| i as f32 * 0.5 - 7.0).collect(),
            g: (0..d).map(|i| 1.0 - i as f32 * 0.25).collect(),
        };
        let mut buf = Vec::new();
        encode_resync(&r, &mut buf).unwrap();
        assert_eq!(decode_resync(&buf).unwrap(), r);
        buf
    };
    assert!(decode_downlink(&frame).is_ok());
    fuzz_decoder(&frame, &|b| {
        let _ = decode_resync(b);
    });
    fuzz_decoder(&frame, &|b| {
        let _ = decode_downlink(b);
    });
}

#[test]
fn handshake_and_switch_frames_survive_truncation_and_bit_flips() {
    let wh = encode_worker_hello();
    assert!(decode_worker_hello(&wh).is_ok());
    fuzz_decoder(&wh, &|b| {
        let _ = decode_worker_hello(b);
    });

    let ms = encode_mech_switch(&MechSwitch {
        round: 500,
        mech: "CLAG(Top-4,zeta=2)".into(),
        spec: "clag:top4:2".into(),
    })
    .unwrap();
    assert!(decode_mech_switch(&ms).is_ok());
    fuzz_decoder(&ms, &|b| {
        let _ = decode_mech_switch(b);
    });
}

#[test]
fn round_replies_survive_truncation_and_bit_flips() {
    let up = encode_uplink_with(
        &UplinkMsg {
            worker_id: 0,
            update: threepc::mechanisms::Update::Replace {
                g: vec![1.0, 2.0, 3.0, 4.0],
                bits: 128,
                wire: threepc::mechanisms::ReplaceWire::Dense,
            },
            g_err: 0.25,
        },
        WireValueCoding::RawF32,
    );
    let grad = vec![0.5f32, -1.0, 2.0, 0.0];
    for loss in [None, Some(3.5)] {
        let mut body = Vec::new();
        encode_round_reply(9, &up, &grad, loss, &mut body);
        assert!(split_round_reply(&body).is_ok());
        fuzz_decoder(&body, &|b| {
            // Chain into the uplink decoder like the leader link does.
            if let Ok(r) = split_round_reply(b) {
                let _ = decode_uplink(r.upframe);
            }
        });
    }
}

#[test]
fn checkpoint_files_survive_truncation_and_bit_flips() {
    let cp = Checkpoint {
        t: 42,
        grad_norm_sq: 0.125,
        x: vec![1.0, -2.0, 3.5],
        g_sum: vec![-1.0, 0.5, 3.0],
        worker_g: vec![(0, vec![0.0, 0.5, 1.0]), (1, vec![-1.0, 0.0, 2.0])],
        worker_bits: vec![(0, 4096), (1, 8192)],
        bits_down: 1920,
        wire_bytes_up: 333,
        wire_bytes_down: 444,
    };
    let bytes = cp.to_bytes();
    assert!(Checkpoint::from_bytes(&bytes).is_ok());
    fuzz_decoder(&bytes, &|b| {
        let _ = Checkpoint::from_bytes(b);
    });
}

/// The re-attach worker hello (flags byte + previous worker id) must
/// survive the battery through the same decoder the fresh 7-byte hello
/// uses — a flipped flag bit must never panic the accept path.
#[test]
fn reattach_worker_hellos_survive_truncation_and_bit_flips() {
    use threepc::coordinator::protocol::encode_worker_hello_reattach;
    for prev in [0u32, 3, u32::MAX] {
        let buf = encode_worker_hello_reattach(prev);
        assert_eq!(decode_worker_hello(&buf).unwrap().reattach, Some(prev));
        fuzz_decoder(&buf, &|b| {
            let _ = decode_worker_hello(b);
        });
    }
}

/// Every journal-record family (admission, phase transition, checkpoint
/// pointer, terminal result) must survive the battery — a daemon replays
/// these bytes from disk at startup, where a torn or corrupted tail must
/// surface as `Err`, never a panic or an unbounded allocation.
#[test]
fn journal_records_survive_truncation_and_bit_flips() {
    use threepc::coordinator::protocol::{
        decode_journal_record, encode_journal_record, JournalRecord,
    };
    let records = [
        JournalRecord::Admit {
            id: 7,
            spec: "problem=quad:4:30:0.01:0.5:21;mech=ef21:top3;rounds=40".into(),
        },
        JournalRecord::Phase { id: 7, phase: SessionPhase::Running, detail: String::new() },
        JournalRecord::Phase {
            id: 7,
            phase: SessionPhase::Failed,
            detail: "worker 2: connection reset".into(),
        },
        JournalRecord::Ckpt { id: 7, t: 125, path: "/tmp/sessions/7.ckpt".into() },
        JournalRecord::Result(SessionResult {
            id: 7,
            rounds_run: 400,
            converged: false,
            diverged: false,
            final_grad_norm_sq: 1e-7,
            total_bits_up: 987_654,
            total_bits_down: 321_000,
            wire_bytes_up: 55_555,
            wire_bytes_down: 44_444,
            error: None,
        }),
    ];
    for r in &records {
        let buf = encode_journal_record(r).unwrap();
        assert_eq!(&decode_journal_record(&buf).unwrap(), r);
        fuzz_decoder(&buf, &|b| {
            let _ = decode_journal_record(b);
        });
    }
}

#[test]
fn client_frames_survive_truncation_and_bit_flips() {
    let frames = [
        ClientFrame::Hello,
        ClientFrame::Submit {
            spec: "problem=quad:4:30:0.01:0.5:21;mech=ef21:top3;rounds=40".into(),
        },
        ClientFrame::Status { id: 7 },
        ClientFrame::Attach { id: u64::MAX },
        ClientFrame::Cancel { id: 0 },
    ];
    for f in &frames {
        let buf = encode_client_frame(f).unwrap();
        assert_eq!(&decode_client_frame(&buf).unwrap(), f);
        fuzz_decoder(&buf, &|b| {
            let _ = decode_client_frame(b);
        });
    }
}

#[test]
fn serve_frames_survive_truncation_and_bit_flips() {
    let record = RoundRecord {
        t: 12,
        grad_norm_sq: 0.5,
        g_err: 0.125,
        bits_up_cum: 1024.0,
        bits_up_max: 2048,
        bits_down_cum: 960.0,
        skipped_frac: 0.25,
        loss: Some(3.5),
        mech_switch: Some("EF21(Top-4)".into()),
        absent: vec![1, 3],
    };
    let frames = [
        ServeFrame::Hello,
        ServeFrame::Status(SessionStatus {
            id: 3,
            phase: SessionPhase::Running,
            rounds: 17,
            detail: "mid-run".into(),
        }),
        ServeFrame::Metric(MetricUpdate { id: 3, record: record.clone() }),
        ServeFrame::Metric(MetricUpdate {
            id: 4,
            record: RoundRecord { loss: None, mech_switch: None, absent: vec![], ..record },
        }),
        ServeFrame::Result(SessionResult {
            id: 3,
            rounds_run: 40,
            converged: true,
            diverged: false,
            final_grad_norm_sq: 1e-9,
            total_bits_up: 123_456,
            total_bits_down: 7_890,
            wire_bytes_up: 4_321,
            wire_bytes_down: 987,
            error: Some("server shutdown".into()),
        }),
        ServeFrame::Reject { code: RejectCode::BadSpec, reason: "unknown key 'turbo'".into() },
        ServeFrame::Reject { code: RejectCode::UnknownSession, reason: "no session".into() },
    ];
    for f in &frames {
        let buf = encode_serve_frame(f).unwrap();
        assert_eq!(&decode_serve_frame(&buf).unwrap(), f);
        fuzz_decoder(&buf, &|b| {
            let _ = decode_serve_frame(b);
        });
    }
}
