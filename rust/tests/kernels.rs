//! Serial ≡ sharded bit-identity for the kernel layer — the fixed-chunk
//! accumulation contract, pinned.
//!
//! Every reduction and elementwise kernel must produce *bit-identical*
//! results whether it runs serially or fanned out over a [`ShardPool`]
//! of any helper count, for sizes straddling the chunk boundaries
//! (d = 1, 4095, 4096, 4097, …, 2^20). This is what makes coordinate
//! sharding trace-invisible (the `session_api` thread-count equivalence
//! test pins the end-to-end consequence).

use threepc::kernels::{self, ShardPool, Shards, CHUNK, SHARD_MIN};
use threepc::util::rng::Pcg64;

fn vec_f32(rng: &mut Pcg64, d: usize, scale: f64) -> Vec<f32> {
    (0..d).map(|_| (rng.normal() * scale) as f32).collect()
}

fn vec_f64(rng: &mut Pcg64, d: usize) -> Vec<f64> {
    (0..d).map(|_| rng.normal()).collect()
}

/// The boundary-straddling size ladder from the issue, plus sizes above
/// the dispatch threshold so the pool actually engages. (Dispatch
/// requires `len >= SHARD_MIN` *and* more chunks than helpers; smaller
/// sizes exercise the contract trivially — sharded call = serial path —
/// while the pool-partition test below drives them through the pool
/// directly.)
fn sizes() -> Vec<usize> {
    vec![
        1,
        CHUNK - 1,       // 4095
        CHUNK,           // 4096
        CHUNK + 1,       // 4097
        SHARD_MIN,       // smallest size that can dispatch (1 helper)
        SHARD_MIN + 1,
        3 * CHUNK + 17,
        8 * CHUNK,       // dispatches for every helper count used here
        1 << 20,         // the large-d bench regime
        (1 << 20) + CHUNK - 1,
    ]
}

fn assert_bits_eq_f32(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: coordinate {i}: {x} vs {y}");
    }
}

fn assert_bits_eq_f64(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: coordinate {i}: {x} vs {y}");
    }
}

#[test]
fn reductions_serial_equals_sharded_bit_for_bit() {
    let mut rng = Pcg64::seed(0x5eed5);
    for helpers in [1usize, 2, 3] {
        let pool = ShardPool::new(helpers);
        let sh: Shards<'_> = Some(&pool);
        for d in sizes() {
            let x = vec_f32(&mut rng, d, 1.5);
            let y = vec_f32(&mut rng, d, 0.7);
            let v = vec_f64(&mut rng, d);
            let label = format!("d={d} helpers={helpers}");
            assert_eq!(
                kernels::sqnorm(None, &x).to_bits(),
                kernels::sqnorm(sh, &x).to_bits(),
                "sqnorm {label}"
            );
            assert_eq!(
                kernels::dist_sq(None, &x, &y).to_bits(),
                kernels::dist_sq(sh, &x, &y).to_bits(),
                "dist_sq {label}"
            );
            assert_eq!(
                kernels::dot(None, &x, &y).to_bits(),
                kernels::dot(sh, &x, &y).to_bits(),
                "dot {label}"
            );
            assert_eq!(
                kernels::asum(None, &x).to_bits(),
                kernels::asum(sh, &x).to_bits(),
                "asum {label}"
            );
            assert_eq!(
                kernels::sqnorm_scaled_f64(None, &v, 0.125).to_bits(),
                kernels::sqnorm_scaled_f64(sh, &v, 0.125).to_bits(),
                "sqnorm_scaled_f64 {label}"
            );
        }
    }
}

#[test]
fn elementwise_serial_equals_sharded_bit_for_bit() {
    let mut rng = Pcg64::seed(0xe1e);
    let pool = ShardPool::new(2);
    let sh: Shards<'_> = Some(&pool);
    for d in sizes() {
        let x = vec_f32(&mut rng, d, 1.0);
        let y = vec_f32(&mut rng, d, 2.0);
        let label = format!("d={d}");

        // axpy
        let mut a = y.clone();
        let mut b = y.clone();
        kernels::axpy(None, 0.37, &x, &mut a);
        kernels::axpy(sh, 0.37, &x, &mut b);
        assert_bits_eq_f32(&a, &b, &format!("axpy {label}"));

        // diff
        let mut a = vec![0.0f32; d];
        let mut b = vec![0.0f32; d];
        kernels::diff(None, &x, &y, &mut a);
        kernels::diff(sh, &x, &y, &mut b);
        assert_bits_eq_f32(&a, &b, &format!("diff {label}"));

        // scale / copy / add_assign
        let mut a = x.clone();
        let mut b = x.clone();
        kernels::scale(None, &mut a, -1.25);
        kernels::scale(sh, &mut b, -1.25);
        assert_bits_eq_f32(&a, &b, &format!("scale {label}"));
        kernels::copy(None, &y, &mut a);
        kernels::copy(sh, &y, &mut b);
        assert_bits_eq_f32(&a, &b, &format!("copy {label}"));
        kernels::add_assign(None, &x, &mut a);
        kernels::add_assign(sh, &x, &mut b);
        assert_bits_eq_f32(&a, &b, &format!("add_assign {label}"));

        // f64 folds
        let seed_acc = vec_f64(&mut rng, d);
        let mut a = seed_acc.clone();
        let mut b = seed_acc.clone();
        kernels::fold_f64(None, &mut a, &x);
        kernels::fold_f64(sh, &mut b, &x);
        assert_bits_eq_f64(&a, &b, &format!("fold_f64 {label}"));
        kernels::fold_delta_f64(None, &mut a, &x, &y);
        kernels::fold_delta_f64(sh, &mut b, &x, &y);
        assert_bits_eq_f64(&a, &b, &format!("fold_delta_f64 {label}"));
        kernels::add_f64(None, &mut a, &seed_acc);
        kernels::add_f64(sh, &mut b, &seed_acc);
        assert_bits_eq_f64(&a, &b, &format!("add_f64 {label}"));

        // scaled_to_f32 readout
        let mut fa = vec![0.0f32; d];
        let mut fb = vec![0.0f32; d];
        kernels::scaled_to_f32(None, &a, 1.0 / 3.0, &mut fa);
        kernels::scaled_to_f32(sh, &b, 1.0 / 3.0, &mut fb);
        assert_bits_eq_f32(&fa, &fb, &format!("scaled_to_f32 {label}"));

        // fill
        kernels::fill_f64(None, &mut a, 0.0);
        kernels::fill_f64(sh, &mut b, 0.0);
        assert_bits_eq_f64(&a, &b, &format!("fill_f64 {label}"));
    }
}

/// Below [`SHARD_MIN`] the public API never dispatches, so the chunk
/// partition itself is exercised directly through the pool for the
/// boundary sizes: every coordinate must be visited exactly once, in
/// chunk-aligned ranges.
#[test]
fn pool_partitions_boundary_sizes_exactly() {
    use std::sync::atomic::{AtomicU32, Ordering};
    let pool = ShardPool::new(2);
    for d in [1usize, CHUNK - 1, CHUNK, CHUNK + 1, 2 * CHUNK + 3] {
        let hits: Vec<AtomicU32> = (0..d).map(|_| AtomicU32::new(0)).collect();
        let ran = pool.try_run(d, &|s, e| {
            assert_eq!(s % CHUNK, 0, "d={d}: shard start must be chunk-aligned");
            assert!(e - s <= CHUNK && e <= d, "d={d}: bad shard [{s}, {e})");
            for h in &hits[s..e] {
                h.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(ran, "d={d}: idle pool must accept the dispatch");
        assert!(
            hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
            "d={d}: every coordinate exactly once"
        );
    }
}

/// Helper-count invariance: the same reduction over 1, 2 and 3 helpers
/// (different shard interleavings at runtime) lands on identical bits.
#[test]
fn helper_count_is_unobservable_in_reduction_bits() {
    let mut rng = Pcg64::seed(77);
    let d = (1 << 18) + 4095;
    let x = vec_f32(&mut rng, d, 3.0);
    let serial = kernels::sqnorm(None, &x).to_bits();
    for helpers in [1usize, 2, 3, 5] {
        let pool = ShardPool::new(helpers);
        // Repeat: chunk→thread assignment varies run to run; bits must not.
        for rep in 0..5 {
            assert_eq!(
                kernels::sqnorm(Some(&pool), &x).to_bits(),
                serial,
                "helpers={helpers} rep={rep}"
            );
        }
    }
}

/// Vectorized ≡ scalar, bit for bit, on every kernel the SIMD layer
/// covers. The public entry points dispatch to `std::arch` lanes when
/// available (see `THREEPC_SIMD`); [`kernels::reference`] mirrors the
/// always-scalar bodies. Equal bits across the issue's size ladder is
/// the whole vectorization contract — when the SIMD path is disabled
/// (env toggle, or a host without the features) both sides run the
/// same scalar code and the test pins that the mirrors stay in sync.
#[test]
fn vectorized_equals_scalar_reference_bit_for_bit() {
    use threepc::kernels::reference;
    let mut rng = Pcg64::seed(0x51d);
    eprintln!("simd_active = {}", kernels::simd_active());
    for d in [1usize, CHUNK - 1, CHUNK, CHUNK + 1, 1 << 20] {
        let x = vec_f32(&mut rng, d, 1.3);
        let y = vec_f32(&mut rng, d, 0.9);
        let label = format!("d={d}");

        // Reductions.
        assert_eq!(
            kernels::sqnorm(None, &x).to_bits(),
            reference::sqnorm(&x).to_bits(),
            "sqnorm {label}"
        );
        assert_eq!(
            kernels::dist_sq(None, &x, &y).to_bits(),
            reference::dist_sq(&x, &y).to_bits(),
            "dist_sq {label}"
        );
        assert_eq!(
            kernels::dot(None, &x, &y).to_bits(),
            reference::dot(&x, &y).to_bits(),
            "dot {label}"
        );

        // f32 elementwise.
        let mut a = y.clone();
        let mut b = y.clone();
        kernels::axpy(None, -0.62, &x, &mut a);
        reference::axpy(-0.62, &x, &mut b);
        assert_bits_eq_f32(&a, &b, &format!("axpy {label}"));
        let mut a = vec![0.0f32; d];
        let mut b = vec![0.0f32; d];
        kernels::diff(None, &x, &y, &mut a);
        reference::diff(&x, &y, &mut b);
        assert_bits_eq_f32(&a, &b, &format!("diff {label}"));

        // f64 folds and the readout.
        let seed_acc = vec_f64(&mut rng, d);
        let mut a = seed_acc.clone();
        let mut b = seed_acc.clone();
        kernels::fold_f64(None, &mut a, &x);
        reference::fold_f64(&mut b, &x);
        assert_bits_eq_f64(&a, &b, &format!("fold_f64 {label}"));
        kernels::fold_delta_f64(None, &mut a, &x, &y);
        reference::fold_delta_f64(&mut b, &x, &y);
        assert_bits_eq_f64(&a, &b, &format!("fold_delta_f64 {label}"));
        let mut fa = vec![0.0f32; d];
        let mut fb = vec![0.0f32; d];
        kernels::scaled_to_f32(None, &a, 0.2, &mut fa);
        reference::scaled_to_f32(&b, 0.2, &mut fb);
        assert_bits_eq_f32(&fa, &fb, &format!("scaled_to_f32 {label}"));
    }
}

/// Two threads hammering one pool: the loser of the try-lock degrades
/// to serial, so both still compute correct (identical) bits.
#[test]
fn concurrent_dispatch_degrades_to_serial_not_to_wrong_bits() {
    let mut rng = Pcg64::seed(9);
    let d = 1 << 17;
    let x = vec_f32(&mut rng, d, 1.0);
    let y = vec_f32(&mut rng, d, 1.0);
    let expect_x = kernels::sqnorm(None, &x).to_bits();
    let expect_y = kernels::sqnorm(None, &y).to_bits();
    let pool = ShardPool::new(2);
    std::thread::scope(|s| {
        let pool = &pool;
        let (x, y) = (&x, &y);
        let a = s.spawn(move || {
            for _ in 0..50 {
                assert_eq!(kernels::sqnorm(Some(pool), x).to_bits(), expect_x);
            }
        });
        let b = s.spawn(move || {
            for _ in 0..50 {
                assert_eq!(kernels::sqnorm(Some(pool), y).to_bits(), expect_y);
            }
        });
        a.join().unwrap();
        b.join().unwrap();
    });
}
