//! Steady-state allocation regression tests for the zero-allocation
//! round pipeline.
//!
//! A counting global allocator (thread-local counters, so parallel test
//! threads don't bleed into each other's measurements) pins the core
//! perf invariant: once the `MechScratch` buffer pool is warm,
//! `MechWorker::round_acc` performs **zero** heap allocations for
//! allocation-free mechanisms — EF21 over Top-K (the paper's flagship)
//! and the CLAG skip path (lazy aggregation's whole point is that a
//! skipped round costs nothing, now including allocator traffic).

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use threepc::compressors::{Ctx, CtxInfo};
use threepc::coordinator::{
    Framed, InitPolicy, RoundAggregate, TrainConfig, Transport, TransportLink, WorkerState,
};
use threepc::kernels::{ShardPool, Shards};
use threepc::mechanisms::{parse_mechanism, MechWorker, Update};
use threepc::problems::quadratic;
use threepc::util::rng::Pcg64;

/// Counts alloc/realloc events per thread. Dealloc is uncounted (frees
/// are fine; it's acquisition traffic that fragments and serializes).
struct CountingAlloc;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

#[inline]
fn bump() {
    ALLOCS.with(|c| c.set(c.get() + 1));
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        bump();
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Allocation events on this thread while `f` runs.
fn count_allocs<F: FnMut()>(mut f: F) -> u64 {
    let before = ALLOCS.with(|c| c.get());
    f();
    ALLOCS.with(|c| c.get()) - before
}

/// Drive `rounds` rounds of `worker` over a fixed gradient cycle,
/// accumulating into `delta` like the transport does.
fn drive(
    worker: &mut MechWorker,
    grads: &[Vec<f32>],
    rng: &mut Pcg64,
    info: CtxInfo,
    delta: &mut Vec<f64>,
    t0: u64,
    rounds: u64,
) {
    drive_sh(worker, grads, rng, info, delta, t0, rounds, None);
}

/// [`drive`] with a coordinate shard pool attached to the context.
#[allow(clippy::too_many_arguments)]
fn drive_sh(
    worker: &mut MechWorker,
    grads: &[Vec<f32>],
    rng: &mut Pcg64,
    info: CtxInfo,
    delta: &mut Vec<f64>,
    t0: u64,
    rounds: u64,
    sh: Shards<'_>,
) {
    for t in t0..t0 + rounds {
        let grad = &grads[(t as usize) % grads.len()];
        let mut ctx = Ctx::new(info, rng, t).sharded(sh);
        worker.round_acc(grad, &mut ctx, delta);
    }
}

fn gradient_cycle(d: usize, n: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut meta = Pcg64::seed(seed);
    (0..n)
        .map(|_| (0..d).map(|_| meta.normal() as f32).collect())
        .collect()
}

#[test]
fn ef21_topk_round_acc_is_allocation_free_at_steady_state() {
    let d = 512;
    let info = CtxInfo::single(d);
    let map = parse_mechanism("ef21:top16").unwrap();
    let grads = gradient_cycle(d, 7, 0xa110c);
    let mut worker = MechWorker::new(map, vec![0.0f32; d], grads[0].clone());
    let mut rng = Pcg64::seed(1);
    let mut delta = vec![0.0f64; d];

    // Warm the scratch pool: the first rounds grow each buffer class to
    // its steady size.
    drive(&mut worker, &grads, &mut rng, info, &mut delta, 0, 10);

    let allocs = count_allocs(|| {
        drive(&mut worker, &grads, &mut rng, info, &mut delta, 10, 25);
    });
    assert_eq!(
        allocs, 0,
        "EF21(Top-16) steady-state round_acc must not touch the allocator"
    );
    // Sanity: the rounds actually produced sparse increments.
    assert!(matches!(worker.last_update(), Update::Increment { .. }));
}

#[test]
fn clag_skip_path_is_allocation_free() {
    let d = 256;
    let info = CtxInfo::single(d);
    // ζ so large the trigger never fires → every round is a Keep.
    let map = parse_mechanism("clag:top8:1e12").unwrap();
    let grads = gradient_cycle(d, 5, 0xc1a6);
    let mut worker = MechWorker::new(map, vec![0.0f32; d], grads[0].clone());
    let mut rng = Pcg64::seed(2);
    let mut delta = vec![0.0f64; d];

    drive(&mut worker, &grads, &mut rng, info, &mut delta, 0, 5);
    assert!(
        matches!(worker.last_update(), Update::Keep),
        "huge ζ must put CLAG on the skip path"
    );

    let allocs = count_allocs(|| {
        drive(&mut worker, &grads, &mut rng, info, &mut delta, 5, 25);
    });
    assert_eq!(allocs, 0, "a skipped CLAG round must cost zero allocations");
}

/// The `Framed` transport runs its whole round on the calling thread
/// (encode → decode → fold), so the pooled codec path is pinnable too:
/// persistent frame buffer, recycled decode slot, reused mirror and
/// reconstruction buffers. (The `InProcess` link crosses threads, so
/// its recycling is exercised by the equivalence suites instead —
/// thread-local counters can't observe pool threads.)
#[test]
fn framed_link_round_is_allocation_free_at_steady_state() {
    let n = 4;
    let d = 128;
    let suite = quadratic::generate(n, d, 1e-2, 0.5, 3);
    let map = parse_mechanism("ef21:top4").unwrap();
    let workers: Vec<WorkerState> = (0..n)
        .map(|i| {
            WorkerState::new(
                i,
                n,
                suite.problem.locals[i].clone(),
                map.clone(),
                &suite.problem.x0,
                InitPolicy::FullGradient,
                7,
            )
        })
        .collect();
    let cfg = TrainConfig::default();
    let mut link = Framed::default().connect(workers, d, &cfg).unwrap();
    let mut agg = RoundAggregate::new(d, n);
    let x = vec![0.05f32; d];
    for t in 0..8u64 {
        link.round(&x, t, false, &mut agg).unwrap();
    }
    let allocs = count_allocs(|| {
        for t in 8..28u64 {
            link.round(&x, t, false, &mut agg).expect("steady-state framed round");
        }
    });
    assert_eq!(allocs, 0, "steady-state Framed rounds must not allocate");
}

/// The coordinate-sharded path must stay inside the zero-allocation
/// envelope: dispatching a kernel to the shard pool is unpark + atomics
/// against pre-allocated state, and the per-dispatcher chunk-partial
/// buffer is a thread-local that warms once. Counters are thread-local,
/// so this pins the dispatcher side (the worker thread driving the
/// round); helper threads execute only the dispatched chunk arithmetic,
/// which owns no allocation sites.
#[test]
fn sharded_round_acc_is_allocation_free_at_steady_state() {
    // d ≥ SHARD_MIN so the kernels actually dispatch to the pool.
    let d = 8 * threepc::kernels::CHUNK;
    assert!(d >= threepc::kernels::SHARD_MIN);
    let info = CtxInfo::single(d);
    let pool = ShardPool::new(2);
    let sh: Shards<'_> = Some(&pool);
    let map = parse_mechanism("ef21:top64").unwrap();
    let grads = gradient_cycle(d, 3, 0x54a6d);
    let mut worker = MechWorker::new(map, vec![0.0f32; d], grads[0].clone());
    let mut rng = Pcg64::seed(4);
    let mut delta = vec![0.0f64; d];

    // Warm the scratch pool AND the dispatcher's thread-local partial
    // buffer (first sharded reduction grows it once).
    drive_sh(&mut worker, &grads, &mut rng, info, &mut delta, 0, 10, sh);

    let allocs = count_allocs(|| {
        drive_sh(&mut worker, &grads, &mut rng, info, &mut delta, 10, 20, sh);
    });
    assert_eq!(
        allocs, 0,
        "steady-state sharded round_acc must not allocate on the dispatcher thread"
    );
    assert!(matches!(worker.last_update(), Update::Increment { .. }));

    // And the sharded trajectory is the serial trajectory, bit for bit
    // (the kernels' fixed-chunk contract, end to end): replay the same
    // rounds serially from a fresh worker and compare the final state.
    let map2 = parse_mechanism("ef21:top64").unwrap();
    let mut serial = MechWorker::new(map2, vec![0.0f32; d], grads[0].clone());
    let mut rng2 = Pcg64::seed(4);
    let mut delta2 = vec![0.0f64; d];
    drive(&mut serial, &grads, &mut rng2, info, &mut delta2, 0, 30);
    assert_eq!(serial.g().len(), worker.g().len());
    for (i, (a, b)) in serial.g().iter().zip(worker.g()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "g[{i}] diverged: {a} vs {b}");
    }
    for (i, (a, b)) in delta2.iter().zip(&delta).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "delta[{i}] diverged: {a} vs {b}");
    }
}

#[test]
fn clag_fire_path_is_allocation_free_at_steady_state() {
    let d = 256;
    let info = CtxInfo::single(d);
    // ζ = 0 → fires every round (EF21 behaviour), exercising the
    // trigger + compress pipeline.
    let map = parse_mechanism("clag:top8:0.0").unwrap();
    let grads = gradient_cycle(d, 5, 0xf19e);
    let mut worker = MechWorker::new(map, vec![0.0f32; d], grads[0].clone());
    let mut rng = Pcg64::seed(3);
    let mut delta = vec![0.0f64; d];

    drive(&mut worker, &grads, &mut rng, info, &mut delta, 0, 10);

    let allocs = count_allocs(|| {
        drive(&mut worker, &grads, &mut rng, info, &mut delta, 10, 25);
    });
    assert_eq!(allocs, 0, "CLAG fire path must be allocation-free once warm");
}
