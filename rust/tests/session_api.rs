//! Integration tests for the composable `TrainSession` API: transport
//! equivalence across the whole mechanism family, observer control
//! flow, and checkpoint persistence through a real training run.

use threepc::coordinator::{
    Checkpoint, CheckpointObserver, Framed, InProcess, RoundCtx, RoundFlow, RoundObserver,
    StopReason, StreamObserver, TrainConfig, TrainSession,
};
use threepc::mechanisms::parse_mechanism;
use threepc::problems::quadratic;

fn cfg(gamma: f64, rounds: usize) -> TrainConfig {
    // threads = 1 pins the f64 fold order, making InProcess and Framed
    // traces comparable at full precision.
    TrainConfig { gamma, max_rounds: rounds, threads: 1, seed: 13, ..TrainConfig::default() }
}

/// The serializing transport reproduces the in-memory transport's
/// optimization trajectory for every mechanism family member: the codec
/// is semantically lossless along the whole training path.
#[test]
fn framed_matches_inprocess_for_every_mechanism() {
    let suite = quadratic::generate(6, 30, 1e-2, 0.5, 21);
    for spec in [
        "gd",
        "dcgd:top3",
        "ef21:top3",
        "lag:2.0",
        "clag:top3:2.0",
        "v1:top3",
        "v2:rand3:top3",
        "v3:ef21:top3;top2",
        "v4:top3:top2",
        "v5:0.3:top3",
        "marina:0.3:rand3",
    ] {
        let c = cfg(0.02, 25);
        let a = TrainSession::builder(&suite.problem)
            .mechanism(parse_mechanism(spec).unwrap())
            .config(c.clone())
            .transport(InProcess::new(1))
            .run();
        let b = TrainSession::builder(&suite.problem)
            .mechanism(parse_mechanism(spec).unwrap())
            .config(c)
            .transport(Framed::default())
            .run();
        assert_eq!(a.rounds_run, b.rounds_run, "{spec}");
        assert!(b.wire_bytes_up > 0, "{spec}");
        assert_eq!(a.wire_bytes_up, 0, "{spec}");
        for (ra, rb) in a.records.iter().zip(&b.records) {
            assert_eq!(ra.grad_norm_sq, rb.grad_norm_sq, "{spec} round {}", ra.t);
            assert_eq!(ra.skipped_frac, rb.skipped_frac, "{spec} round {}", ra.t);
            assert_eq!(ra.g_err, rb.g_err, "{spec} round {}", ra.t);
            assert_eq!(ra.bits_down_cum, rb.bits_down_cum, "{spec} round {}", ra.t);
            // Framing overhead makes measured billing strictly larger.
            assert!(rb.bits_up_cum > ra.bits_up_cum, "{spec} round {}", ra.t);
        }
    }
}

/// Framed billing is measured bytes: total_bits_up (beyond g⁰ init)
/// must equal 8 × the transport's serialized byte count.
#[test]
fn framed_bills_exactly_its_measured_bytes() {
    let suite = quadratic::generate(5, 20, 1e-2, 0.5, 3);
    let r = TrainSession::builder(&suite.problem)
        .mechanism(parse_mechanism("clag:top3:2.0").unwrap())
        .config(cfg(0.02, 15))
        .transport(Framed::default())
        .run();
    let init_bits: u64 = 5 * 32 * 20; // FullGradient g⁰ sync, n = 5, d = 20
    assert_eq!(r.total_bits_up - init_bits, 8 * r.wire_bytes_up);
}

/// Observers stream every round and can stop the session; built-in
/// stop rules win over user observers on the same round.
#[test]
fn observers_stream_and_stop() {
    let suite = quadratic::generate(4, 20, 1e-2, 0.5, 9);
    let mut rounds_seen = 0usize;

    struct HardStop {
        at: usize,
    }
    impl RoundObserver for HardStop {
        fn on_round(&mut self, ctx: &mut RoundCtx<'_>) -> RoundFlow {
            if ctx.snap.t >= self.at {
                RoundFlow::Stop(StopReason::Custom("enough".into()))
            } else {
                RoundFlow::Continue
            }
        }
    }

    let r = TrainSession::builder(&suite.problem)
        .mechanism(parse_mechanism("ef21:top2").unwrap())
        .config(cfg(0.02, 100))
        .observer(StreamObserver::new(|_s: &threepc::coordinator::RoundSnapshot<'_>| {
            rounds_seen += 1;
        }))
        .observer(HardStop { at: 6 })
        .run();
    assert_eq!(r.rounds_run, 7);
    assert_eq!(rounds_seen, 7);
    assert!(!r.converged && !r.diverged);
    // The stopped round is always recorded, even off-cadence.
    assert_eq!(r.records.last().unwrap().t, 6);
}

/// Coordinate sharding is trace-invisible: with `threads > n` the
/// surplus threads shard the d-dimensional hot loops, and the kernels'
/// fixed-chunk accumulation contract guarantees the folded f64 bits are
/// identical to the unsharded run — for *any* thread count. Pinned at
/// full precision (`assert_eq!` on the f64 records), on a dimension
/// large enough that the kernels really dispatch to the pool.
#[test]
fn coordinate_sharding_leaves_traces_bit_identical() {
    // Enough chunks that the kernels dispatch even for the largest
    // helper count below (the gate requires chunks > helpers).
    let d = 12 * threepc::kernels::CHUNK;
    let n = 4;
    let suite = quadratic::generate(n, d, 1e-3, 0.5, 31);
    for spec in ["ef21:top128", "clag:top128:2.0", "gd", "lag:4.0"] {
        let run = |threads: usize| {
            let c = TrainConfig {
                gamma: 0.01,
                max_rounds: 12,
                threads: 1, // overridden by the transport's own count
                seed: 13,
                ..TrainConfig::default()
            };
            TrainSession::builder(&suite.problem)
                .mechanism(parse_mechanism(spec).unwrap())
                .config(c)
                .transport(InProcess::new(threads))
                .run()
        };
        // threads = n → no helpers (the pre-sharding layout);
        // threads > n → same worker partition + 2 or 8 shard helpers.
        let base = run(n);
        for threads in [n + 2, n + 8] {
            let sharded = run(threads);
            assert_eq!(base.rounds_run, sharded.rounds_run, "{spec} threads={threads}");
            for (ra, rb) in base.records.iter().zip(&sharded.records) {
                assert_eq!(
                    ra.grad_norm_sq.to_bits(),
                    rb.grad_norm_sq.to_bits(),
                    "{spec} threads={threads} round {}",
                    ra.t
                );
                assert_eq!(
                    ra.g_err.to_bits(),
                    rb.g_err.to_bits(),
                    "{spec} threads={threads} round {}",
                    ra.t
                );
                assert_eq!(ra.bits_up_cum, rb.bits_up_cum, "{spec} threads={threads}");
                assert_eq!(ra.skipped_frac, rb.skipped_frac, "{spec} threads={threads}");
            }
            for (a, b) in base.final_x.iter().zip(&sharded.final_x) {
                assert_eq!(a.to_bits(), b.to_bits(), "{spec} threads={threads} final_x");
            }
        }
    }
}

/// Checkpoints persist the full `(x, g_i)` optimizer state and match
/// the session's own final state when written on the last round.
#[test]
fn checkpoint_captures_final_state() {
    let suite = quadratic::generate(3, 16, 1e-2, 0.5, 5);
    let path = std::env::temp_dir().join(format!("threepc-session-ckpt-{}.bin", std::process::id()));
    let rounds = 9;
    let r = TrainSession::builder(&suite.problem)
        .mechanism(parse_mechanism("clag:top2:1.0").unwrap())
        .config(cfg(0.02, rounds))
        .observer(CheckpointObserver::new(rounds - 1, path.clone()))
        .run();
    let cp = Checkpoint::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(cp.t, rounds - 1);
    assert_eq!(cp.x, r.final_x, "checkpointed iterate is the final iterate");
    assert_eq!(cp.worker_g.len(), 3);
    let mut ids: Vec<usize> = cp.worker_g.iter().map(|&(id, _)| id).collect();
    ids.sort_unstable();
    assert_eq!(ids, vec![0, 1, 2]);
}
