//! Regenerates Tables 3-4 generator constants (table3) at bench scale and times it.
//! Full-scale regeneration: `threepc exp table3` (see DESIGN.md section 4).

#[path = "benchkit/mod.rs"]
mod benchkit;

fn main() {
    benchkit::run_experiment("table3", &["--d", "300"]);
}
