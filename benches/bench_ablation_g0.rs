//! Regenerates g0 init ablation (ablation-g0) at bench scale and times it.
//! Full-scale regeneration: `threepc exp ablation-g0` (see DESIGN.md section 4).

#[path = "benchkit/mod.rs"]
mod benchkit;

fn main() {
    benchkit::run_experiment("ablation-g0", &["--rounds", "1500"]);
}
