//! Regenerates Fig 15 3PCv4 vs EF21 0.02d (fig15) at bench scale and times it.
//! Full-scale regeneration: `threepc exp fig15` (see DESIGN.md section 4).

#[path = "benchkit/mod.rs"]
mod benchkit;

fn main() {
    benchkit::run_experiment("fig15", &["--d", "100", "--rounds", "1200", "--multipliers", "1,4,64", "--tol", "5e-3"]);
}
