//! Regenerates Fig 6 quadratic EF21 (fig6) at bench scale and times it.
//! Full-scale regeneration: `threepc exp fig6` (see DESIGN.md section 4).

#[path = "benchkit/mod.rs"]
mod benchkit;

fn main() {
    benchkit::run_experiment("fig6", &["--d", "100", "--rounds", "1200", "--noise-scales", "0.8", "--multipliers", "1,4,64", "--tol", "5e-3"]);
}
