//! Regenerates Fig 8 quadratic 3PCv2 K=d/n (fig8) at bench scale and times it.
//! Full-scale regeneration: `threepc exp fig8` (see DESIGN.md section 4).

#[path = "benchkit/mod.rs"]
mod benchkit;

fn main() {
    benchkit::run_experiment("fig8", &["--d", "100", "--rounds", "1200", "--noise-scales", "0.8", "--multipliers", "1,4,64", "--tol", "5e-3"]);
}
