//! Regenerates Fig 4 MARINA vs 3PCv5 (fig4) at bench scale and times it.
//! Full-scale regeneration: `threepc exp fig4` (see DESIGN.md section 4).

#[path = "benchkit/mod.rs"]
mod benchkit;

fn main() {
    benchkit::run_experiment("fig4", &["--workers", "10", "--rounds", "40", "--multipliers", "0.001,0.0001"]);
}
