//! Regenerates Table 1 certificates (table1) at bench scale and times it.
//! Full-scale regeneration: `threepc exp table1` (see DESIGN.md section 4).

#[path = "benchkit/mod.rs"]
mod benchkit;

fn main() {
    benchkit::run_experiment("table1", &["--draws", "2000"]);
}
