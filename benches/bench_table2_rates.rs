//! Regenerates Table 2 rate verification (table2) at bench scale and times it.
//! Full-scale regeneration: `threepc exp table2` (see DESIGN.md section 4).

#[path = "benchkit/mod.rs"]
mod benchkit;

fn main() {
    benchkit::run_experiment("table2", &["--rounds", "600"]);
}
