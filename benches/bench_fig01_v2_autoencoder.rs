//! Regenerates Fig 1/5 autoencoder 3PCv2 vs EF21 (fig1) at bench scale and times it.
//! Full-scale regeneration: `threepc exp fig1` (see DESIGN.md section 4).

#[path = "benchkit/mod.rs"]
mod benchkit;

fn main() {
    benchkit::run_experiment("fig1", &["--workers", "10", "--rounds", "40", "--multipliers", "0.001,0.0001"]);
}
