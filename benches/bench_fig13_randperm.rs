//! Regenerates Fig 13 Rand-Perm tuning 0.02d (fig13) at bench scale and times it.
//! Full-scale regeneration: `threepc exp fig13` (see DESIGN.md section 4).

#[path = "benchkit/mod.rs"]
mod benchkit;

fn main() {
    benchkit::run_experiment("fig13", &["--d", "100", "--rounds", "1200", "--multipliers", "1,4,64", "--tol", "5e-3"]);
}
