//! Hot-path microbenchmarks — the perf-pass instrument (EXPERIMENTS.md
//! §Perf). Targets from DESIGN.md §7 / PERF.md:
//!   * Top-K selection ≥ 1e8 coords/s (quickselect, no full sort);
//!   * mechanism apply dominated by the compressor, not allocation —
//!     the scratch-pool `apply_into`/`compress_into` path is measured
//!     against the allocating compat wrappers;
//!   * server fold O(nnz);
//!   * full coordinator round at cheap-gradient settings dominated by
//!     gradient compute, coordination overhead < 10%.
//!
//! Emits `BENCH_hotpath.json` (per-case medians + derived figures) —
//! the machine-readable perf trajectory CI uploads per commit. Run with
//! `BENCH_SMOKE=1` for the reduced-iteration CI mode.

#[path = "benchkit/mod.rs"]
mod benchkit;

use threepc::compressors::{CVec, Contractive, Ctx, CtxInfo, MechScratch, TopK, WireValueCoding};
use threepc::coordinator::{TrainConfig, TrainSession};
use threepc::kernels::{self, ShardPool};
use threepc::mechanisms::{parse_mechanism, recycle_update, ThreePointMap, Update};
use threepc::problems::quadratic;
use threepc::util::rng::Pcg64;

fn main() {
    let mut report = benchkit::JsonReport::new("hotpath");
    println!("== hot path microbenches ==");
    // Which chunk bodies the kernel layer dispatched to (AVX/NEON vs
    // scalar) — the bits are identical either way, the speed is not.
    println!("[bench] vectorized kernels active: {}", kernels::simd_active());
    let d = 25_088;
    let mut rng = Pcg64::seed(1);
    let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();

    // Top-K selection throughput.
    for k in [251usize, 2508] {
        let top = TopK::new(k);
        let s = benchkit::measure(
            &format!("topk select k={k} d={d}"),
            10,
            benchkit::scaled(200),
            || {
                std::hint::black_box(top.select(&x));
            },
        );
        let cps = benchkit::throughput(&s, d);
        println!("    → {:.1}e6 coords/s", cps / 1e6);
        report.push(&s, &[("coords_per_s", cps)]);
    }

    // Full compressor: allocating compat path vs the pooled
    // `compress_into` hot path (RNG seeding hoisted out of the closures
    // so the cases measure compression, not generator setup).
    let info = CtxInfo::single(d);
    let top = TopK::new(251);
    let mut r2 = Pcg64::seed(2);
    let s = benchkit::measure("topk compress k=251 (alloc compat)", 10, benchkit::scaled(200), || {
        let mut ctx = Ctx::new(info, &mut r2, 0);
        std::hint::black_box(top.compress(&x, &mut ctx));
    });
    report.push(&s, &[]);
    let mut scratch = MechScratch::new();
    let mut slot = CVec::Zero { dim: 0 };
    let s = benchkit::measure("topk compress_into k=251 (pooled)", 10, benchkit::scaled(200), || {
        let mut ctx = Ctx::with_scratch(info, &mut r2, 0, &mut scratch);
        top.compress_into(&x, &mut ctx, &mut slot);
        std::hint::black_box(&slot);
    });
    report.push(&s, &[]);

    // Select→wire-encode: the two-step compress-then-encode the framed
    // transport used to run per round vs the fused fast path that
    // gathers the selected (index, value) pairs straight into the frame
    // buffer. Byte-identical output (pinned by codec_props); the fused
    // case measures what skipping the intermediate CVec walk buys.
    let mut wirebuf = Vec::new();
    let s = benchkit::measure(
        "topk compress_into+encode_with k=251 (two-step)",
        10,
        benchkit::scaled(200),
        || {
            let mut ctx = Ctx::with_scratch(info, &mut r2, 0, &mut scratch);
            top.compress_into(&x, &mut ctx, &mut slot);
            wirebuf.clear();
            slot.encode_with(WireValueCoding::RawF32, &mut wirebuf);
            std::hint::black_box(&wirebuf);
        },
    );
    report.push(&s, &[]);
    let s = benchkit::measure(
        "topk compress_encode_into k=251 (fused)",
        10,
        benchkit::scaled(200),
        || {
            let mut ctx = Ctx::with_scratch(info, &mut r2, 0, &mut scratch);
            wirebuf.clear();
            top.compress_encode_into(&x, &mut ctx, WireValueCoding::RawF32, &mut slot, &mut wirebuf);
            std::hint::black_box(&wirebuf);
        },
    );
    report.push(&s, &[]);

    // Mechanism apply (EF21, CLAG skip and fire paths) through the
    // recycled-slot scratch pipeline — the path every transport drives.
    let ef = parse_mechanism("ef21:top251").unwrap();
    let h = vec![0.0f32; d];
    let y = vec![0.0f32; d];
    let mut r3 = Pcg64::seed(3);
    let mut scratch = MechScratch::new();
    let mut upd = Update::Keep;
    let s = benchkit::measure("EF21 apply_into d=25088 (pooled)", 10, benchkit::scaled(200), || {
        let mut ctx = Ctx::with_scratch(info, &mut r3, 0, &mut scratch);
        recycle_update(&mut ctx, &mut upd);
        ef.apply_into(&h, &y, &x, &mut ctx, &mut upd);
        std::hint::black_box(&upd);
    });
    report.push(&s, &[]);
    let clag = parse_mechanism("clag:top251:1e9").unwrap(); // huge ζ → always skips
    let s = benchkit::measure("CLAG apply_into (skip path) d=25088", 10, benchkit::scaled(200), || {
        let mut ctx = Ctx::with_scratch(info, &mut r3, 0, &mut scratch);
        recycle_update(&mut ctx, &mut upd);
        clag.apply_into(&x, &x, &x, &mut ctx, &mut upd);
        std::hint::black_box(&upd);
    });
    report.push(&s, &[]);

    // End-to-end round latency on the quadratic suite (cheap gradients
    // → upper-bounds the coordination overhead). The n=1000 case is the
    // acceptance metric for the zero-allocation round pipeline.
    println!("\n== coordinator round latency (cheap gradients → coordination overhead) ==");
    for (n, threads) in [(100usize, 1usize), (100, 0), (1000, 0)] {
        let suite = quadratic::generate(n, 1000, 1e-4, 0.5, 7);
        let map = parse_mechanism("clag:top20:4.0").unwrap();
        let rounds = 30;
        let cfg = TrainConfig {
            gamma: 1e-3,
            max_rounds: rounds,
            threads,
            seed: 1,
            ..TrainConfig::default()
        };
        let s = benchkit::measure(
            &format!(
                "train {rounds} rounds n={n} d=1000 threads={}",
                if threads == 0 { "auto".into() } else { threads.to_string() }
            ),
            1,
            benchkit::scaled(5),
            || {
                std::hint::black_box(
                    TrainSession::builder(&suite.problem)
                        .mechanism(map.clone())
                        .config(cfg.clone())
                        .run(),
                );
            },
        );
        let ms_per_round = s.median.as_secs_f64() * 1e3 / rounds as f64;
        println!("    → {ms_per_round:.2} ms/round");
        report.push(&s, &[("ms_per_round", ms_per_round)]);
    }

    // Mean-aggregation fold cost alone.
    println!("\n== server fold ==");
    let deltas: Vec<Vec<f64>> = (0..8).map(|i| vec![i as f64; d]).collect();
    let mut server = threepc::coordinator::Server::new(vec![0.0f32; d], &[&x], &[0]);
    let s = benchkit::measure("fold 8 thread-partials d=25088", 10, benchkit::scaled(300), || {
        for dd in &deltas {
            server.fold_delta(std::hint::black_box(dd));
        }
    });
    report.push(&s, &[]);

    // Per-kernel cases at the large-d regime: serial vs sharded over
    // the machine's spare threads. The contract says the bits are
    // identical; these cases measure what the fan-out buys.
    println!("\n== kernel layer, d = 2^20 (serial vs sharded) ==");
    let dbig = 1usize << 20;
    let helpers = std::thread::available_parallelism()
        .map(|p| p.get().saturating_sub(1))
        .unwrap_or(1)
        .max(1);
    let pool = ShardPool::new(helpers);
    let mut rb = Pcg64::seed(11);
    let xb: Vec<f32> = (0..dbig).map(|_| rb.normal() as f32).collect();
    let yb: Vec<f32> = (0..dbig).map(|_| rb.normal() as f32).collect();
    let mut accb = vec![0.0f64; dbig];
    let mut outb = vec![0.0f32; dbig];
    for (mode, sh) in [("serial", None), ("sharded", Some(&pool))] {
        let s = benchkit::measure(
            &format!("kernel sqnorm d=2^20 ({mode})"),
            3,
            benchkit::scaled(40),
            || {
                std::hint::black_box(kernels::sqnorm(sh, &xb));
            },
        );
        report.push(&s, &[("coords_per_s", benchkit::throughput(&s, dbig))]);
        let s = benchkit::measure(
            &format!("kernel dist_sq d=2^20 ({mode})"),
            3,
            benchkit::scaled(40),
            || {
                std::hint::black_box(kernels::dist_sq(sh, &xb, &yb));
            },
        );
        report.push(&s, &[("coords_per_s", benchkit::throughput(&s, dbig))]);
        let s = benchkit::measure(
            &format!("kernel fold_f64 d=2^20 ({mode})"),
            3,
            benchkit::scaled(40),
            || {
                kernels::fold_f64(sh, &mut accb, &xb);
                std::hint::black_box(&accb);
            },
        );
        report.push(&s, &[("coords_per_s", benchkit::throughput(&s, dbig))]);
        let s = benchkit::measure(
            &format!("kernel diff d=2^20 ({mode})"),
            3,
            benchkit::scaled(40),
            || {
                kernels::diff(sh, &xb, &yb, &mut outb);
                std::hint::black_box(&outb);
            },
        );
        report.push(&s, &[("coords_per_s", benchkit::throughput(&s, dbig))]);
    }
    drop(pool);
    drop((xb, yb, accb, outb));

    // The large-d/small-n round — the regime the coordinate sharding
    // targets (d = 2^20, n = 4). `threads=1` is the serial reference;
    // `threads=auto` uses every core: worker-parallel up to n, and any
    // surplus cores shard coordinates. On a multi-core runner (cores >
    // n) the auto case is the ≥2× acceptance metric; CI's perf-smoke
    // step gates `ms_per_round` of both cases against the checked-in
    // BENCH_hotpath.json baseline.
    println!("\n== large-d round latency (d=2^20, n=4) ==");
    {
        let n = 4;
        let suite = quadratic::generate(n, dbig, 1e-4, 0.5, 7);
        let map = parse_mechanism("ef21:top4096").unwrap();
        let rounds = 10;
        for (label, threads) in [("threads=1", 1usize), ("threads=auto", 0)] {
            let cfg = TrainConfig {
                gamma: 1e-3,
                max_rounds: rounds,
                threads,
                seed: 1,
                ..TrainConfig::default()
            };
            let s = benchkit::measure(
                &format!("train {rounds} rounds n={n} d=1048576 {label}"),
                1,
                benchkit::scaled(3),
                || {
                    std::hint::black_box(
                        TrainSession::builder(&suite.problem)
                            .mechanism(map.clone())
                            .config(cfg.clone())
                            .run(),
                    );
                },
            );
            let ms_per_round = s.median.as_secs_f64() * 1e3 / rounds as f64;
            println!("    → {ms_per_round:.2} ms/round");
            report.push(&s, &[("ms_per_round", ms_per_round)]);
        }
    }

    match report.write(".") {
        Ok(path) => println!("\n[bench] wrote {path}"),
        Err(e) => eprintln!("[bench] failed to write JSON report: {e}"),
    }
}
