//! Hot-path microbenchmarks — the perf-pass instrument (EXPERIMENTS.md
//! §Perf). Targets from DESIGN.md §7:
//!   * Top-K selection ≥ 1e8 coords/s (quickselect, no full sort);
//!   * mechanism apply dominated by the compressor, not allocation;
//!   * server fold O(nnz);
//!   * full coordinator round at (n=100, d=25088) dominated by gradient
//!     compute, coordination overhead < 10%.

#[path = "benchkit/mod.rs"]
mod benchkit;

use std::sync::Arc;
use threepc::compressors::{Contractive, Ctx, CtxInfo, TopK};
use threepc::coordinator::{TrainConfig, TrainSession};
use threepc::mechanisms::parse_mechanism;
use threepc::problems::quadratic;
use threepc::util::rng::Pcg64;

fn main() {
    println!("== hot path microbenches ==");
    let d = 25_088;
    let mut rng = Pcg64::seed(1);
    let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();

    // Top-K selection throughput.
    for k in [251usize, 2508] {
        let top = TopK::new(k);
        let s = benchkit::measure(&format!("topk select k={k} d={d}"), 10, 200, || {
            std::hint::black_box(top.select(&x));
        });
        println!("    → {:.1}e6 coords/s", benchkit::throughput(&s, d) / 1e6);
    }

    // Full compressor (select + gather + alloc).
    let info = CtxInfo::single(d);
    let top = TopK::new(251);
    benchkit::measure("topk compress k=251 (alloc+gather)", 10, 200, || {
        let mut r = Pcg64::seed(2);
        let mut ctx = Ctx::new(info, &mut r, 0);
        std::hint::black_box(top.compress(&x, &mut ctx));
    });

    // Mechanism apply (EF21, CLAG skip and fire paths).
    let ef = parse_mechanism("ef21:top251").unwrap();
    let h = vec![0.0f32; d];
    let y = vec![0.0f32; d];
    benchkit::measure("EF21 apply d=25088", 10, 200, || {
        let mut r = Pcg64::seed(3);
        let mut ctx = Ctx::new(info, &mut r, 0);
        std::hint::black_box(ef.apply(&h, &y, &x, &mut ctx));
    });
    let clag = parse_mechanism("clag:top251:1e9").unwrap(); // huge ζ → always skips
    benchkit::measure("CLAG apply (skip path) d=25088", 10, 200, || {
        let mut r = Pcg64::seed(3);
        let mut ctx = Ctx::new(info, &mut r, 0);
        std::hint::black_box(clag.apply(&x, &x, &x, &mut ctx));
    });

    // End-to-end round latency, n = 100 workers on the quadratic suite
    // (cheap gradients → upper-bounds the coordination overhead).
    println!("\n== coordinator round latency (cheap gradients → coordination overhead) ==");
    for (n, threads) in [(100usize, 1usize), (100, 0), (1000, 0)] {
        let suite = quadratic::generate(n, 1000, 1e-4, 0.5, 7);
        let map = parse_mechanism("clag:top20:4.0").unwrap();
        let rounds = 30;
        let cfg = TrainConfig { gamma: 1e-3, max_rounds: rounds, threads, seed: 1, ..TrainConfig::default() };
        let s = benchkit::measure(
            &format!("train {rounds} rounds n={n} d=1000 threads={}", if threads == 0 { "auto".into() } else { threads.to_string() }),
            1,
            5,
            || {
                std::hint::black_box(
                    TrainSession::builder(&suite.problem)
                        .mechanism(map.clone())
                        .config(cfg.clone())
                        .run(),
                );
            },
        );
        println!(
            "    → {:.2} ms/round",
            s.median.as_secs_f64() * 1e3 / rounds as f64
        );
    }

    // Mean-aggregation fold cost alone.
    println!("\n== server fold ==");
    let deltas: Vec<Vec<f64>> = (0..8).map(|i| vec![i as f64; d]).collect();
    let g0: Vec<&[f32]> = Vec::new();
    drop(g0);
    let mut server = threepc::coordinator::Server::new(vec![0.0f32; d], &[&x], &[0]);
    benchkit::measure("fold 8 thread-partials d=25088", 10, 300, || {
        for dd in &deltas {
            server.fold_delta(std::hint::black_box(dd));
        }
    });

    let _ = Arc::strong_count(&ef);
}
