//! Regenerates Fig 10 (K1,K2) tuning (fig10) at bench scale and times it.
//! Full-scale regeneration: `threepc exp fig10` (see DESIGN.md section 4).

#[path = "benchkit/mod.rs"]
mod benchkit;

fn main() {
    benchkit::run_experiment("fig10", &["--d", "100", "--rounds", "1200", "--multipliers", "1,4,64", "--tol", "5e-3"]);
}
