//! Regenerates Figs 21-24 bit budget (fig21) at bench scale and times it.
//! Full-scale regeneration: `threepc exp fig21` (see DESIGN.md section 4).

#[path = "benchkit/mod.rs"]
mod benchkit;

fn main() {
    benchkit::run_experiment("fig21", &["--budget-mbits", "1.0", "--rounds", "800", "--zetas", "4,64", "--multipliers", "1,16,256"]);
}
