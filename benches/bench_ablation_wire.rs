//! Regenerates Wire-format ablation (ablation-wire) at bench scale and times it.
//! Full-scale regeneration: `threepc exp ablation-wire` (see DESIGN.md section 4).

#[path = "benchkit/mod.rs"]
mod benchkit;

fn main() {
    benchkit::run_experiment("ablation-wire", &[]);
}
