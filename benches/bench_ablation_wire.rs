//! Wire-format ablation, measured for real: codec encode/decode
//! throughput across sparsity levels (including the sparse→dense cap
//! crossover the accounting assumes) and the per-round overhead of the
//! serializing `Framed` transport against the in-memory `InProcess`
//! pool on the quadratic suite.
//!
//! The declared-bits side of the ablation (`threepc exp ablation-wire`)
//! stays in the experiment harness; this bench times the bytes.

#[path = "benchkit/mod.rs"]
mod benchkit;

use threepc::compressors::{CVec, WireValueCoding};
use threepc::coordinator::{
    decode_uplink, encode_uplink, encode_uplink_with, Framed, InProcess, TrainConfig,
    TrainSession, UplinkMsg,
};
use threepc::mechanisms::{parse_mechanism, Update};
use threepc::problems::quadratic;
use threepc::util::rng::Pcg64;

fn sparse_msg(d: usize, k: usize, rng: &mut Pcg64) -> UplinkMsg {
    let idx: Vec<u32> = rng.sample_indices(d, k).into_iter().map(|i| i as u32).collect();
    let val: Vec<f32> = (0..k).map(|_| rng.normal() as f32).collect();
    let inc = CVec::Sparse { dim: d, idx, val };
    let bits = inc.wire_bits();
    UplinkMsg { worker_id: 0, update: Update::Increment { inc, bits }, g_err: 0.0 }
}

fn main() {
    println!("== wire codec throughput (d = 25088) ==");
    let d = 25_088;
    let mut rng = Pcg64::seed(7);
    // K sweep spans the sparse regime up to past the cap crossover
    // (K/d = 32/(32+15) ≈ 0.68 at d = 25088).
    for k in [251usize, 2508, 12544, 20000] {
        let msg = sparse_msg(d, k, &mut rng);
        let bytes = encode_uplink(&msg);
        let s = benchkit::measure(&format!("encode k={k} ({} B)", bytes.len()), 10, 200, || {
            std::hint::black_box(encode_uplink(std::hint::black_box(&msg)));
        });
        println!("    → {:.1} MB/s", benchkit::throughput(&s, bytes.len()) / 1e6);
        let s = benchkit::measure(&format!("decode k={k}"), 10, 200, || {
            std::hint::black_box(decode_uplink(std::hint::black_box(&bytes)).unwrap());
        });
        println!("    → {:.1} MB/s", benchkit::throughput(&s, bytes.len()) / 1e6);
    }

    // Dense replace frames (GD/LAG fire path).
    let dense = UplinkMsg {
        worker_id: 0,
        update: Update::Replace {
            g: (0..d).map(|i| i as f32).collect(),
            bits: 32 * d as u64,
            wire: threepc::mechanisms::ReplaceWire::Dense,
        },
        g_err: 0.0,
    };
    let bytes = encode_uplink(&dense);
    let s = benchkit::measure(&format!("encode dense ({} B)", bytes.len()), 10, 200, || {
        std::hint::black_box(encode_uplink(std::hint::black_box(&dense)));
    });
    println!("    → {:.1} MB/s", benchkit::throughput(&s, bytes.len()) / 1e6);

    // Natural value coding: 9-bit sign+exponent vs raw f32 for
    // power-of-two payloads (what natural-compressed mechanisms emit).
    println!("\n== natural value coding: raw f32 vs 9-bit sign+exponent (d = 25088) ==");
    for k in [251usize, 2508, 12544] {
        let idx: Vec<u32> = rng.sample_indices(d, k).into_iter().map(|i| i as u32).collect();
        let val: Vec<f32> = (0..k)
            .map(|i| {
                let mag = 2.0f32.powi((i % 17) as i32 - 8);
                if i % 2 == 0 {
                    mag
                } else {
                    -mag
                }
            })
            .collect();
        let inc = CVec::Sparse { dim: d, idx, val };
        let bits = inc.wire_bits();
        let msg = UplinkMsg { worker_id: 0, update: Update::Increment { inc, bits }, g_err: 0.0 };
        let raw = encode_uplink(&msg);
        let nat = encode_uplink_with(&msg, WireValueCoding::Natural);
        println!(
            "  k={k}: raw {} B vs natural {} B ({:.2}x smaller)",
            raw.len(),
            nat.len(),
            raw.len() as f64 / nat.len() as f64
        );
        let s = benchkit::measure(&format!("encode natural k={k}"), 10, 200, || {
            std::hint::black_box(encode_uplink_with(
                std::hint::black_box(&msg),
                WireValueCoding::Natural,
            ));
        });
        println!("    → {:.1} MB/s", benchkit::throughput(&s, nat.len()) / 1e6);
        let s = benchkit::measure(&format!("decode natural k={k}"), 10, 200, || {
            std::hint::black_box(decode_uplink(std::hint::black_box(&nat)).unwrap());
        });
        println!("    → {:.1} MB/s", benchkit::throughput(&s, nat.len()) / 1e6);
    }

    // Framed vs InProcess per-round overhead: cheap gradients make the
    // difference pure transport cost.
    println!("\n== Framed vs InProcess per-round overhead (quadratic suite) ==");
    for (n, dq) in [(20usize, 1000usize), (100, 1000)] {
        let suite = quadratic::generate(n, dq, 1e-4, 0.5, 7);
        let rounds = 30;
        let cfg = TrainConfig {
            gamma: 1e-3,
            max_rounds: rounds,
            threads: 1,
            seed: 1,
            ..TrainConfig::default()
        };
        let map = parse_mechanism("clag:top20:4.0").unwrap();
        let s_in = benchkit::measure(&format!("inprocess n={n} d={dq} ({rounds} rounds)"), 1, 5, || {
            std::hint::black_box(
                TrainSession::builder(&suite.problem)
                    .mechanism(map.clone())
                    .config(cfg.clone())
                    .transport(InProcess::new(1))
                    .run(),
            );
        });
        let s_fr = benchkit::measure(&format!("framed    n={n} d={dq} ({rounds} rounds)"), 1, 5, || {
            std::hint::black_box(
                TrainSession::builder(&suite.problem)
                    .mechanism(map.clone())
                    .config(cfg.clone())
                    .transport(Framed::default())
                    .run(),
            );
        });
        let per_round_in = s_in.median.as_secs_f64() * 1e3 / rounds as f64;
        let per_round_fr = s_fr.median.as_secs_f64() * 1e3 / rounds as f64;
        println!(
            "    → {per_round_in:.3} ms/round in-process, {per_round_fr:.3} ms/round framed \
             ({:.2}x serialization overhead)",
            per_round_fr / per_round_in
        );
    }
}
