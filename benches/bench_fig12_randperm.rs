//! Regenerates Fig 12 Rand-Perm tuning (fig12) at bench scale and times it.
//! Full-scale regeneration: `threepc exp fig12` (see DESIGN.md section 4).

#[path = "benchkit/mod.rs"]
mod benchkit;

fn main() {
    benchkit::run_experiment("fig12", &["--d", "100", "--rounds", "1200", "--multipliers", "1,4,64", "--tol", "5e-3"]);
}
