//! Regenerates Fig 3 EF21 sparsifiers (fig3) at bench scale and times it.
//! Full-scale regeneration: `threepc exp fig3` (see DESIGN.md section 4).

#[path = "benchkit/mod.rs"]
mod benchkit;

fn main() {
    benchkit::run_experiment("fig3", &["--workers", "10", "--rounds", "40", "--multipliers", "0.001,0.0001"]);
}
