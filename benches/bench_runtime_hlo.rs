//! HLO executor step latency vs the native backend (the DESIGN.md §5
//! native-vs-HLO ablation): per-gradient latency of the AOT-compiled
//! JAX/Pallas artifacts executed through PJRT, against the hand-written
//! Rust gradients, plus the end-to-end round cost of each backend.

#[path = "benchkit/mod.rs"]
mod benchkit;

use threepc::problems::{LocalProblem, QuadLocal};
use threepc::runtime::{DeviceService, HloQuad, Manifest};
use threepc::util::rng::Pcg64;

fn main() {
    let manifest = match Manifest::load(threepc::runtime::default_artifacts_dir()) {
        Ok(m) => m,
        Err(e) => {
            println!("skipping HLO bench: {e}");
            return;
        }
    };
    let dev = DeviceService::start().expect("PJRT CPU client");
    let d = manifest.prop("quad_grad", "d").unwrap();
    let mut rng = Pcg64::seed(1);
    let b: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
    let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();

    let native = QuadLocal::new(1.3, 0.7, b.clone());
    let hlo = HloQuad::new(dev.handle(), &manifest, "bench", 1.3, 0.7, b).unwrap();

    // Perturb x per call so the executors' same-iterate memo caches
    // (which serve the coordinator's loss+grad pairing) never hit.
    let mut out = vec![0.0f32; d];
    let mut x = x;
    let mut tick = 0f32;
    let sn = benchkit::measure(&format!("native quad grad d={d}"), 20, 500, || {
        tick += 1e-6;
        x[0] += tick;
        native.grad(std::hint::black_box(&x), &mut out);
    });
    let sh = benchkit::measure(&format!("HLO (Pallas stencil via PJRT) quad grad d={d}"), 20, 500, || {
        tick += 1e-6;
        x[0] += tick;
        hlo.grad(std::hint::black_box(&x), &mut out);
    });
    println!(
        "    → PJRT dispatch overhead ≈ {:.1} µs/call ({}x native; gradient math is ~{} ns)",
        (sh.median.as_secs_f64() - sn.median.as_secs_f64()) * 1e6,
        (sh.median.as_secs_f64() / sn.median.as_secs_f64()).round(),
        sn.median.as_nanos()
    );

    // Logreg: a realistically-sized gradient (m×d work) where the PJRT
    // call cost amortises.
    let m = manifest.prop("logreg_a9a", "m").unwrap();
    let dl = manifest.prop("logreg_a9a", "d").unwrap();
    let rows: Vec<f32> = (0..m * dl).map(|_| rng.normal() as f32).collect();
    let labels: Vec<f32> = (0..m).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
    let native = threepc::problems::LogReg::new(rows.clone(), labels.clone(), dl, 0.1);
    let hlo = threepc::runtime::HloLogReg::new(dev.handle(), &manifest, "a9a", "bench", rows, labels)
        .unwrap();
    let mut xl: Vec<f32> = (0..dl).map(|_| rng.normal() as f32).collect();
    let mut outl = vec![0.0f32; dl];
    let sn = benchkit::measure(&format!("native logreg grad m={m} d={dl}"), 10, 200, || {
        tick += 1e-6;
        xl[0] += tick;
        native.grad(std::hint::black_box(&xl), &mut outl);
    });
    let sh = benchkit::measure(&format!("HLO (fused Pallas kernel) logreg grad m={m} d={dl}"), 10, 200, || {
        tick += 1e-6;
        xl[0] += tick;
        hlo.grad(std::hint::black_box(&xl), &mut outl);
    });
    println!(
        "    → HLO/native ratio {:.2} (amortised)",
        sh.median.as_secs_f64() / sn.median.as_secs_f64()
    );
}
