//! Minimal benchmark harness (the offline image has no criterion).
//!
//! `measure` runs warmup + timed iterations and reports median / MAD /
//! min; `run_experiment` times one paper-experiment regeneration
//! end-to-end. Every bench target is `harness = false`, so `cargo bench`
//! executes these `main`s directly.

use std::time::{Duration, Instant};

pub struct Sample {
    pub name: String,
    pub median: Duration,
    pub mad: Duration,
    pub min: Duration,
    pub iters: usize,
}

impl std::fmt::Display for Sample {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<52} median {:>12.3?}  mad {:>10.3?}  min {:>12.3?}  ({} iters)",
            self.name, self.median, self.mad, self.min, self.iters
        )
    }
}

/// Time `f` with `warmup` + `iters` runs; prints and returns the sample.
#[allow(dead_code)]
pub fn measure<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Sample {
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<f64> = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[times.len() / 2];
    let mut dev: Vec<f64> = times.iter().map(|t| (t - median).abs()).collect();
    dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let sample = Sample {
        name: name.to_string(),
        median: Duration::from_secs_f64(median),
        mad: Duration::from_secs_f64(dev[dev.len() / 2]),
        min: Duration::from_secs_f64(times[0]),
        iters: times.len(),
    };
    println!("{sample}");
    sample
}

/// Throughput helper: elements processed per second at the median.
#[allow(dead_code)]
pub fn throughput(sample: &Sample, elements: usize) -> f64 {
    elements as f64 / sample.median.as_secs_f64()
}

/// Time a whole experiment regeneration (the per-figure benches).
#[allow(dead_code)]
pub fn run_experiment(id: &str, args: &[&str]) {
    let parsed = threepc::util::cli::Args::parse(args.iter().map(|s| s.to_string()));
    let t0 = Instant::now();
    threepc::experiments::run(id, &parsed).unwrap_or_else(|e| panic!("experiment {id}: {e:#}"));
    println!("\n[bench] experiment '{id}' regenerated in {:.2?}", t0.elapsed());
}
