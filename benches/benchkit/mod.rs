//! Minimal benchmark harness (the offline image has no criterion).
//!
//! `measure` runs warmup + timed iterations and reports median / MAD /
//! min; `run_experiment` times one paper-experiment regeneration
//! end-to-end. Every bench target is `harness = false`, so `cargo bench`
//! executes these `main`s directly.
//!
//! Two perf-trajectory additions:
//! * [`JsonReport`] — a machine-readable emitter writing
//!   `BENCH_<name>.json` (per-case median/MAD/min in ns plus
//!   bench-specific derived figures like coords/s or ms/round), the
//!   artifact CI uploads so hot-path regressions are diffable across
//!   commits.
//! * [`smoke`]/[`scaled`] — reduced-iteration smoke mode
//!   (`BENCH_SMOKE=1`) so CI can execute every case cheaply; the JSON
//!   records which mode produced it.

use std::time::{Duration, Instant};

/// True when `BENCH_SMOKE` is set (and not "0"): run each case with a
/// fraction of the iterations so CI finishes quickly. Smoke numbers are
/// for liveness, not comparison — the emitted JSON flags them.
#[allow(dead_code)]
pub fn smoke() -> bool {
    std::env::var("BENCH_SMOKE").is_ok_and(|v| v != "0")
}

/// Scale an iteration count for the active mode (min 2 in smoke mode).
#[allow(dead_code)]
pub fn scaled(iters: usize) -> usize {
    if smoke() {
        (iters / 20).max(2)
    } else {
        iters
    }
}

pub struct Sample {
    pub name: String,
    pub median: Duration,
    pub mad: Duration,
    pub min: Duration,
    pub iters: usize,
}

impl std::fmt::Display for Sample {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<52} median {:>12.3?}  mad {:>10.3?}  min {:>12.3?}  ({} iters)",
            self.name, self.median, self.mad, self.min, self.iters
        )
    }
}

/// Time `f` with `warmup` + `iters` runs; prints and returns the sample.
#[allow(dead_code)]
pub fn measure<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Sample {
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<f64> = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[times.len() / 2];
    let mut dev: Vec<f64> = times.iter().map(|t| (t - median).abs()).collect();
    dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let sample = Sample {
        name: name.to_string(),
        median: Duration::from_secs_f64(median),
        mad: Duration::from_secs_f64(dev[dev.len() / 2]),
        min: Duration::from_secs_f64(times[0]),
        iters: times.len(),
    };
    println!("{sample}");
    sample
}

/// Throughput helper: elements processed per second at the median.
#[allow(dead_code)]
pub fn throughput(sample: &Sample, elements: usize) -> f64 {
    elements as f64 / sample.median.as_secs_f64()
}

/// Machine-readable bench report: accumulates cases and writes
/// `BENCH_<name>.json` at the workspace root. Format:
///
/// ```json
/// {"bench":"hotpath","smoke":false,"cases":[
///   {"name":"topk select k=251 d=25088","median_ns":123456,
///    "mad_ns":789,"min_ns":120000,"iters":200,"coords_per_s":2.0e8},
///   ...]}
/// ```
///
/// Derived figures (`coords_per_s`, `ms_per_round`, …) are attached
/// per-case via the `extras` argument of [`JsonReport::push`]. Written
/// with no external deps — names are escaped, non-finite extras become
/// `null`.
#[allow(dead_code)]
pub struct JsonReport {
    bench: String,
    cases: Vec<String>,
}

#[allow(dead_code)]
fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

#[allow(dead_code)]
fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

#[allow(dead_code)]
impl JsonReport {
    pub fn new(bench: &str) -> JsonReport {
        JsonReport { bench: bench.to_string(), cases: Vec::new() }
    }

    /// Record a measured sample plus bench-specific derived figures.
    pub fn push(&mut self, s: &Sample, extras: &[(&str, f64)]) {
        let mut obj = format!(
            "{{\"name\":\"{}\",\"median_ns\":{},\"mad_ns\":{},\"min_ns\":{},\"iters\":{}",
            json_escape(&s.name),
            s.median.as_nanos(),
            s.mad.as_nanos(),
            s.min.as_nanos(),
            s.iters
        );
        for (k, v) in extras {
            obj.push_str(&format!(",\"{}\":{}", json_escape(k), json_f64(*v)));
        }
        obj.push('}');
        self.cases.push(obj);
    }

    /// Write `BENCH_<bench>.json` into `dir` (the workspace root when
    /// run via `cargo bench`). Returns the path written.
    ///
    /// `"source":"measured"` marks the file as a real bench run — the
    /// checked-in baseline starts life as `"source":"bootstrap"` with
    /// null figures (see tools/check_perf_smoke.py), and is armed by
    /// committing a measured file over it.
    pub fn write(&self, dir: &str) -> std::io::Result<String> {
        let path = format!("{dir}/BENCH_{}.json", self.bench);
        let mut out = String::new();
        out.push_str(&format!(
            "{{\"bench\":\"{}\",\"smoke\":{},\"source\":\"measured\",\"cases\":[",
            json_escape(&self.bench),
            smoke()
        ));
        out.push_str(&self.cases.join(","));
        out.push_str("]}\n");
        std::fs::write(&path, out)?;
        Ok(path)
    }
}

/// Time a whole experiment regeneration (the per-figure benches).
#[allow(dead_code)]
pub fn run_experiment(id: &str, args: &[&str]) {
    let parsed = threepc::util::cli::Args::parse(args.iter().map(|s| s.to_string()));
    let t0 = Instant::now();
    threepc::experiments::run(id, &parsed).unwrap_or_else(|e| panic!("experiment {id}: {e:#}"));
    println!("\n[bench] experiment '{id}' regenerated in {:.2?}", t0.elapsed());
}
