//! Regenerates Fig 11 (K1,K2) tuning 0.02d (fig11) at bench scale and times it.
//! Full-scale regeneration: `threepc exp fig11` (see DESIGN.md section 4).

#[path = "benchkit/mod.rs"]
mod benchkit;

fn main() {
    benchkit::run_experiment("fig11", &["--d", "100", "--rounds", "1200", "--multipliers", "1,4,64", "--tol", "5e-3"]);
}
