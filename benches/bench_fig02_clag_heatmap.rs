//! Regenerates Fig 2/17-20 CLAG heatmap (fig2) at bench scale and times it.
//! Full-scale regeneration: `threepc exp fig2` (see DESIGN.md section 4).

#[path = "benchkit/mod.rs"]
mod benchkit;

fn main() {
    benchkit::run_experiment("fig2", &["--ks", "1,11,22", "--zetas", "0,64", "--multipliers", "1,16,256", "--rounds", "500"]);
}
