//! Regenerates Fig 14 3PCv4 vs EF21 (fig14) at bench scale and times it.
//! Full-scale regeneration: `threepc exp fig14` (see DESIGN.md section 4).

#[path = "benchkit/mod.rs"]
mod benchkit;

fn main() {
    benchkit::run_experiment("fig14", &["--d", "100", "--rounds", "1200", "--multipliers", "1,4,64", "--tol", "5e-3"]);
}
