"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (and the logreg block size); fixed-seed numpy
draws keep the suite deterministic. This is the core correctness signal
for the compiled artifacts — the Rust side additionally pins the HLO
output to the native Rust implementations.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.logreg import logreg_grad, pick_block_rows
from compile.kernels.matmul import matmul
from compile.kernels.quad import quad_grad
from compile.kernels import ref


def rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------- logreg


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(min_value=1, max_value=48),
    d=st.integers(min_value=1, max_value=40),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_logreg_kernel_matches_ref(m, d, seed):
    r = rng(seed)
    x = r.normal(size=d).astype(np.float32)
    a = r.normal(size=(m, d)).astype(np.float32)
    y = r.choice([-1.0, 1.0], size=m).astype(np.float32)
    g_k, l_k = logreg_grad(jnp.asarray(x), jnp.asarray(a), jnp.asarray(y), lam=0.1)
    g_r, l_r = ref.logreg_grad_ref(jnp.asarray(x), jnp.asarray(a), jnp.asarray(y), 0.1)
    np.testing.assert_allclose(np.asarray(g_k), np.asarray(g_r), rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(np.asarray(l_k)[0], float(l_r), rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("block_rows", [1, 2, 5, 10])
def test_logreg_blocking_invariant(block_rows):
    """The row-block size must not change the result (pure reduction)."""
    r = rng(7)
    m, d = 10, 6
    x = r.normal(size=d).astype(np.float32)
    a = r.normal(size=(m, d)).astype(np.float32)
    y = r.choice([-1.0, 1.0], size=m).astype(np.float32)
    g, l = logreg_grad(jnp.asarray(x), jnp.asarray(a), jnp.asarray(y), block_rows=block_rows)
    g1, l1 = logreg_grad(jnp.asarray(x), jnp.asarray(a), jnp.asarray(y), block_rows=m)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g1), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(l), np.asarray(l1), rtol=1e-5, atol=1e-6)


def test_logreg_extreme_margins_stable():
    a = np.array([[1000.0], [-1000.0]], dtype=np.float32)
    y = np.array([1.0, -1.0], dtype=np.float32)
    x = np.array([5.0], dtype=np.float32)
    g, l = logreg_grad(jnp.asarray(x), jnp.asarray(a), jnp.asarray(y))
    assert np.isfinite(np.asarray(g)).all()
    assert np.isfinite(np.asarray(l)).all()


def test_pick_block_rows_divides_and_fits():
    for m, d in [(200, 68), (4000, 300), (60, 784), (7, 3)]:
        bm = pick_block_rows(m, d)
        assert m % bm == 0
        assert bm * d * 4 <= 2 * 1024 * 1024 or bm == 1


# ---------------------------------------------------------------- matmul


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(min_value=1, max_value=24),
    k=st.integers(min_value=1, max_value=24),
    n=st.integers(min_value=1, max_value=24),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_matmul_matches_ref(m, k, n, seed):
    r = rng(seed)
    a = r.normal(size=(m, k)).astype(np.float32)
    b = r.normal(size=(k, n)).astype(np.float32)
    out = matmul(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(out), a @ b, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("tiles", [(1, 1, 1), (2, 3, 2), (4, 4, 4), (128, 256, 128)])
def test_matmul_tiling_invariant(tiles):
    r = rng(3)
    a = r.normal(size=(8, 12)).astype(np.float32)
    b = r.normal(size=(12, 4)).astype(np.float32)
    bm, bk, bn = tiles
    out = matmul(jnp.asarray(a), jnp.asarray(b), bm=bm, bk=bk, bn=bn)
    np.testing.assert_allclose(np.asarray(out), a @ b, rtol=1e-5, atol=1e-5)


def test_matmul_f64():
    r = rng(5)
    a = r.normal(size=(4, 4))
    b = r.normal(size=(4, 4))
    out = matmul(jnp.asarray(a, dtype=jnp.float32), jnp.asarray(b, dtype=jnp.float32))
    np.testing.assert_allclose(np.asarray(out), (a @ b).astype(np.float32), rtol=1e-4)


# ------------------------------------------------------------------ quad


@settings(max_examples=25, deadline=None)
@given(
    d=st.integers(min_value=1, max_value=64),
    nu=st.floats(min_value=-5.0, max_value=5.0),
    shift=st.floats(min_value=0.0, max_value=3.0),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_quad_kernel_matches_ref(d, nu, shift, seed):
    r = rng(seed)
    x = r.normal(size=d).astype(np.float32)
    b = r.normal(size=d).astype(np.float32)
    out = quad_grad(jnp.asarray(x), jnp.asarray(b), nu, shift)
    expect = ref.quad_grad_ref(jnp.asarray(x), jnp.asarray(b), nu, shift)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=2e-5, atol=1e-5)


def test_quad_boundaries():
    # d = 1: no neighbours at all.
    out = quad_grad(jnp.asarray([2.0], dtype=jnp.float32),
                    jnp.asarray([0.5], dtype=jnp.float32), 4.0, 1.0)
    # (4/4)*(2*2) + 1*2 - 0.5 = 5.5
    np.testing.assert_allclose(np.asarray(out), [5.5], rtol=1e-6)
