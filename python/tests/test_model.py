"""L2 correctness: model.py (kernel-backed objectives) vs jax.grad of the
plain-jnp losses, plus AOT lowering smoke tests (HLO text is produced and
parseable-looking for every artifact the Makefile builds)."""

import numpy as np
import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def rng(seed=0):
    return np.random.default_rng(seed)


def test_logreg_model_matches_autodiff():
    r = rng(1)
    m, d = 30, 9
    x = r.normal(size=d).astype(np.float32)
    a = r.normal(size=(m, d)).astype(np.float32)
    y = r.choice([-1.0, 1.0], size=m).astype(np.float32)

    def plain_loss(x):
        z = a @ x
        data = jnp.mean(jnp.logaddexp(0.0, -(y * z)))
        x2 = x * x
        return data + 0.1 * jnp.sum(x2 / (1.0 + x2))

    g_auto = jax.grad(plain_loss)(jnp.asarray(x))
    g_model, loss = model.logreg_loss_grad(jnp.asarray(x), jnp.asarray(a), jnp.asarray(y))
    np.testing.assert_allclose(np.asarray(g_model), np.asarray(g_auto), rtol=3e-5, atol=3e-6)
    np.testing.assert_allclose(float(loss), float(plain_loss(jnp.asarray(x))), rtol=1e-5)


def test_ae_model_matches_autodiff():
    r = rng(2)
    d_f, d_e, m = 10, 3, 6
    dim = 2 * d_f * d_e
    params = (r.normal(size=dim) * 0.3).astype(np.float32)
    a = r.random(size=(m, d_f)).astype(np.float32)

    def plain_loss(p):
        d_mat = p[: d_f * d_e].reshape(d_f, d_e)
        e_mat = p[d_f * d_e:].reshape(d_e, d_f)
        rres = a @ e_mat.T @ d_mat.T - a
        return jnp.sum(rres * rres) / m

    g_auto = jax.grad(plain_loss)(jnp.asarray(params))
    g_model, loss = model.ae_loss_grad(jnp.asarray(params), jnp.asarray(a), d_f=d_f, d_e=d_e)
    np.testing.assert_allclose(np.asarray(g_model), np.asarray(g_auto), rtol=3e-4, atol=3e-5)
    np.testing.assert_allclose(float(loss), float(plain_loss(jnp.asarray(params))), rtol=1e-5)


def test_ae_ref_matches_autodiff():
    r = rng(3)
    d_f, d_e, m = 7, 2, 5
    d_mat = (r.normal(size=(d_f, d_e)) * 0.3).astype(np.float32)
    e_mat = (r.normal(size=(d_e, d_f)) * 0.3).astype(np.float32)
    a = r.random(size=(m, d_f)).astype(np.float32)
    gd, ge, loss = ref.ae_loss_grad_ref(jnp.asarray(d_mat), jnp.asarray(e_mat), jnp.asarray(a))

    def plain(dm, em):
        rres = a @ em.T @ dm.T - a
        return jnp.sum(rres * rres) / m

    gd_auto = jax.grad(plain, argnums=0)(jnp.asarray(d_mat), jnp.asarray(e_mat))
    ge_auto = jax.grad(plain, argnums=1)(jnp.asarray(d_mat), jnp.asarray(e_mat))
    np.testing.assert_allclose(np.asarray(gd), np.asarray(gd_auto), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ge), np.asarray(ge_auto), rtol=1e-4, atol=1e-5)


def test_aot_lowering_produces_hlo_text(tmp_path):
    """Smoke: the full AOT path emits HLO text with an ENTRY computation
    for each artifact kind (small shapes for speed)."""
    from compile.aot import to_hlo_text, lower, f32

    lowered = lower(
        lambda x, a, y: model.logreg_loss_grad(x, a, y, lam=0.1),
        f32((5,)), f32((8, 5)), f32((8,)),
    )
    text = to_hlo_text(lowered)
    assert "ENTRY" in text and "HloModule" in text

    lowered = lower(
        lambda p, a: model.ae_loss_grad(p, a, d_f=6, d_e=2),
        f32((24,)), f32((4, 6)),
    )
    assert "ENTRY" in to_hlo_text(lowered)

    lowered = lower(model.quad_gradient, f32((16,)), f32((16,)), f32(()), f32(()))
    assert "ENTRY" in to_hlo_text(lowered)
