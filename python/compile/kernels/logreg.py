"""L1 Pallas kernel: fused logistic-regression gradient + loss.

The training hot-spot of the §6.1 experiments is, per worker and per
round, `z = A x → s = −y·σ(−y z) → g = Aᵀ s` over the worker's shard.
This kernel fuses all three stages in one pass over row-blocks of A, so
each data tile is read from HBM exactly once and both the gradient and
the loss accumulate in VMEM:

  grid = (m / bm,)
  per step i:  A_blk (bm, d) and y_blk (bm,) stream in;
               x (d,) stays resident;
               g (d,) and loss (1,) accumulate in place (their BlockSpec
               index maps are constant, the canonical Pallas reduction
               pattern).

TPU notes (DESIGN.md §Hardware-Adaptation): bm is chosen so the A tile
fits VMEM (bm·d·4 B ≤ ~2 MiB); the matvec pair maps to the MXU as
(bm, d)×(d, 1) products. On this image the kernel runs interpret=True
(CPU PJRT cannot execute Mosaic custom-calls); correctness is what we
validate here, structure is what the perf notes assess.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, a_ref, y_ref, g_ref, loss_ref, *, m_total, lam):
    i = pl.program_id(0)
    a_blk = a_ref[...]            # (bm, d)
    y_blk = y_ref[...]            # (bm,)
    x = x_ref[...]                # (d,)

    z = a_blk @ x                 # (bm,) — MXU matvec
    margins = y_blk * z
    # Stable softplus(-margins) and sigmoid(-margins).
    sp = jnp.logaddexp(0.0, -margins)
    sig = 1.0 / (1.0 + jnp.exp(margins))
    coeff = -y_blk * sig / m_total
    g_partial = coeff @ a_blk     # (d,) — MXU matvec (Aᵀs for the block)
    loss_partial = jnp.sum(sp) / m_total

    @pl.when(i == 0)
    def _init():
        # First block also contributes the regulariser (added once).
        x2 = x * x
        g_ref[...] = g_partial + lam * 2.0 * x / ((1.0 + x2) ** 2)
        loss_ref[...] = jnp.reshape(loss_partial + lam * jnp.sum(x2 / (1.0 + x2)), (1,))

    @pl.when(i != 0)
    def _acc():
        g_ref[...] = g_ref[...] + g_partial
        loss_ref[...] = loss_ref[...] + jnp.reshape(loss_partial, (1,))


def pick_block_rows(m, d, vmem_budget_bytes=2 * 1024 * 1024):
    """Largest divisor-of-m row-block with a_blk under the VMEM budget."""
    cap = max(1, vmem_budget_bytes // (4 * d))
    bm = min(m, cap)
    while m % bm != 0:
        bm -= 1
    return bm


def logreg_grad(x, a, y, lam=0.1, block_rows=None, interpret=True):
    """Fused gradient+loss of Eq. (80). Returns (grad (d,), loss (1,))."""
    m, d = a.shape
    bm = block_rows or pick_block_rows(m, d)
    assert m % bm == 0, f"block_rows {bm} must divide m {m}"
    kernel = functools.partial(_kernel, m_total=float(m), lam=float(lam))
    grad, loss = pl.pallas_call(
        kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),        # x resident
            pl.BlockSpec((bm, d), lambda i: (i, 0)),   # A streams
            pl.BlockSpec((bm,), lambda i: (i,)),       # y streams
        ],
        out_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),        # g accumulates
            pl.BlockSpec((1,), lambda i: (0,)),        # loss accumulates
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d,), a.dtype),
            jax.ShapeDtypeStruct((1,), a.dtype),
        ],
        interpret=interpret,
    )(x, a, y)
    return grad, loss
