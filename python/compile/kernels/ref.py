"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Every kernel in this package has a reference implementation here written
with plain jax.numpy ops only. pytest + hypothesis assert allclose between
kernel and oracle across shapes and dtypes; the Rust integration tests
additionally pin the AOT-compiled HLO to the native Rust implementations.
"""

import jax.numpy as jnp


def logreg_grad_ref(x, a, y, lam):
    """Gradient and loss of non-convex logistic regression (Eq. 80).

    x: (d,) parameters; a: (m, d) features; y: (m,) labels in {-1, +1};
    lam: scalar regulariser weight.

    Returns (grad: (d,), loss: ()).
    """
    z = a @ x
    margins = y * z
    # log(1 + exp(-margins)), numerically stable.
    data_loss = jnp.mean(jnp.logaddexp(0.0, -margins))
    sig = 1.0 / (1.0 + jnp.exp(margins))  # sigmoid(-margins)
    coeff = -y * sig / a.shape[0]
    data_grad = a.T @ coeff
    x2 = x * x
    reg_loss = lam * jnp.sum(x2 / (1.0 + x2))
    reg_grad = lam * 2.0 * x / ((1.0 + x2) ** 2)
    return data_grad + reg_grad, data_loss + reg_loss


def matmul_ref(a, b):
    """Plain matmul oracle: (m, k) @ (k, n)."""
    return a @ b


def quad_grad_ref(x, b, nu, shift):
    """Gradient of the Algorithm-11 quadratic: A x - b with
    A = (nu/4) * tridiag(-1, 2, -1) + shift * I  (O(d) stencil)."""
    left = jnp.concatenate([jnp.zeros_like(x[:1]), x[:-1]])
    right = jnp.concatenate([x[1:], jnp.zeros_like(x[:1])])
    return (nu / 4.0) * (2.0 * x - left - right) + shift * x - b


def ae_loss_grad_ref(d_mat, e_mat, a):
    """Loss and gradients of the linear autoencoder (Eq. 77).

    d_mat: (d_f, d_e); e_mat: (d_e, d_f); a: (m, d_f) data batch.
    Returns (grad_d, grad_e, loss).
    """
    m = a.shape[0]
    z = a @ e_mat.T            # (m, d_e) encodings
    r = z @ d_mat.T - a        # (m, d_f) residuals
    loss = jnp.sum(r * r) / m
    grad_d = 2.0 / m * (r.T @ z)            # (d_f, d_e)
    grad_e = 2.0 / m * (d_mat.T @ r.T @ a)  # (d_e, d_f)
    return grad_d, grad_e, loss
