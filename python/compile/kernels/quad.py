"""L1 Pallas kernel: tridiagonal quadratic gradient (Algorithm 11 suite).

grad = (nu/4) * (2x - shift_left(x) - shift_right(x)) + c*x - b

A 1-D 3-point stencil. The paper's suite uses d = 1000 (4 KB of f32), so
the whole vector comfortably sits in VMEM as a single block and the
shifted reads are in-register rolls; for larger d the kernel falls back
to the same single-block schedule until a halo-exchange variant is
warranted (the suite never needs one).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, b_ref, nu_ref, shift_ref, o_ref):
    x = x_ref[...]
    b = b_ref[...]
    nu = nu_ref[0]
    shift = shift_ref[0]
    d = x.shape[0]
    idx = jnp.arange(d)
    # Shifted neighbours with zero boundaries (roll + mask keeps the
    # whole computation vectorised in VMEM).
    left = jnp.where(idx >= 1, jnp.roll(x, 1), 0.0)
    right = jnp.where(idx < d - 1, jnp.roll(x, -1), 0.0)
    o_ref[...] = (nu / 4.0) * (2.0 * x - left - right) + shift * x - b


def quad_grad(x, b, nu, shift, interpret=True):
    """Gradient of f(x) = ½xᵀAx − bᵀx, A = (nu/4)·T + shift·I.

    `nu`/`shift` may be Python scalars or traced f32 scalars (they enter
    the kernel as (1,)-shaped operands so one AOT artifact serves every
    worker's heterogeneous (ν_i, c))."""
    (d,) = x.shape
    nu_arr = jnp.reshape(jnp.asarray(nu, dtype=x.dtype), (1,))
    shift_arr = jnp.reshape(jnp.asarray(shift, dtype=x.dtype), (1,))
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((d,), x.dtype),
        interpret=interpret,
    )(x, b, nu_arr, shift_arr)
