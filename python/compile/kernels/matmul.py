"""L1 Pallas kernel: tiled matmul.

The autoencoder objective (Eq. 77) is matmul-bound; this kernel is the
building block the L2 model uses for every product (with explicit
transposes where needed, which XLA folds into the operand layouts).

Classic three-loop tiling: grid = (M/bm, N/bn, K/bk) with the K axis
innermost; the (bm, bn) output tile stays resident in VMEM across the K
sweep (constant index map on the k axis — the Pallas accumulation
pattern), while (bm, bk) and (bk, bn) operand tiles stream through.
Tiles default to 128/256 multiples — MXU-shaped on TPU; interpret=True
on this image (see logreg.py header).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += a_ref[...] @ b_ref[...]


def _pick(dim, target):
    """Largest divisor of `dim` that is ≤ target."""
    t = min(dim, target)
    while dim % t != 0:
        t -= 1
    return t


def matmul(a, b, bm=128, bk=256, bn=128, interpret=True):
    """(m, k) @ (k, n) with VMEM tiling. Tile targets shrink to divisors."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {a.shape} @ {b.shape}"
    bm, bk, bn = _pick(m, bm), _pick(k, bk), _pick(n, bn)
    return pl.pallas_call(
        _kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=interpret,
    )(a, b)
