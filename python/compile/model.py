"""L2: the training objectives as JAX programs calling the L1 kernels.

These are the functions `aot.py` lowers to HLO text; the Rust runtime
executes them per worker per round (Python is never on the training
path). Each returns a tuple (lowered with return_tuple=True — the Rust
side unwraps).

Conventions shared with the Rust coordinator:
  * parameters and gradients are f32;
  * the autoencoder parameter vector is [vec(D); vec(E)], row-major,
    matching `rust/src/problems/autoencoder.rs`;
  * logreg labels are ±1.
"""

import jax.numpy as jnp

from compile.kernels.logreg import logreg_grad
from compile.kernels.matmul import matmul
from compile.kernels.quad import quad_grad


def logreg_loss_grad(x, a, y, lam=0.1):
    """Non-convex logistic regression (Eq. 80): returns (grad, loss)."""
    grad, loss = logreg_grad(x, a, y, lam=lam)
    return grad, loss[0]


def quad_gradient(x, b, nu, shift):
    """Algorithm-11 quadratic gradient (tuple for AOT)."""
    return (quad_grad(x, b, nu, shift),)


def ae_loss_grad(params, a, d_f=784, d_e=16):
    """Linear autoencoder (Eq. 77): returns (grad over [vec D; vec E], loss).

    Every matrix product routes through the Pallas matmul kernel.
    """
    nd = d_f * d_e
    d_mat = params[:nd].reshape(d_f, d_e)
    e_mat = params[nd:].reshape(d_e, d_f)
    m = a.shape[0]
    z = matmul(a, e_mat.T)                   # (m, d_e)
    r = matmul(z, d_mat.T) - a               # (m, d_f)
    loss = jnp.sum(r * r) / m
    grad_d = 2.0 / m * matmul(r.T, z)        # (d_f, d_e)
    grad_e = 2.0 / m * matmul(matmul(d_mat.T, r.T), a)  # (d_e, d_f)
    grad = jnp.concatenate([grad_d.reshape(-1), e_grad_flat(grad_e)])
    return grad, loss


def e_grad_flat(grad_e):
    return grad_e.reshape(-1)
