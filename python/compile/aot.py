"""AOT build path: lower the L2 JAX programs to HLO *text* artifacts.

HLO text (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids that xla_extension 0.5.1
(what the published `xla` 0.1.6 crate links) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Artifacts are shape-specialised; `manifest.txt` (key = value lines)
records every artifact's shapes so the Rust runtime can validate its
inputs before compiling. Re-run with different flags to re-specialise:

    python -m compile.aot --out-dir ../artifacts \
        --logreg-m 200 --ae-m 60 --ae-workers 10 --quad-d 1000
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# (name, d) per supported logreg dataset — mirrors
# rust/src/data/mod.rs::LIBSVM_GEOMETRY.
LOGREG_DIMS = {"phishing": 68, "w6a": 300, "a9a": 123, "ijcnn1": 22}


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower(fn, *specs):
    return jax.jit(fn).lower(*specs)


def f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def write(out_dir, name, text, manifest, **meta):
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    manifest.append((name, meta))
    print(f"  wrote {path} ({len(text)} chars)")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--logreg-m", type=int, default=200,
                    help="rows per worker shard (N=4000, n=20 default)")
    ap.add_argument("--ae-m", type=int, default=60,
                    help="autoencoder samples per worker")
    ap.add_argument("--quad-d", type=int, default=1000)
    ap.add_argument("--lam", type=float, default=0.1)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = []

    for name, d in LOGREG_DIMS.items():
        m = args.logreg_m
        lowered = lower(
            lambda x, a, y: model.logreg_loss_grad(x, a, y, lam=args.lam),
            f32((d,)), f32((m, d)), f32((m,)),
        )
        write(args.out_dir, f"logreg_{name}", to_hlo_text(lowered), manifest,
              kind="logreg", m=m, d=d, lam=args.lam)

    # Autoencoder: paper geometry d_f=784, d_e=16, d = 25088.
    d_f, d_e = 784, 16
    dim = 2 * d_f * d_e
    lowered = lower(
        lambda p, a: model.ae_loss_grad(p, a, d_f=d_f, d_e=d_e),
        f32((dim,)), f32((args.ae_m, d_f)),
    )
    write(args.out_dir, "ae_grad", to_hlo_text(lowered), manifest,
          kind="autoencoder", m=args.ae_m, d_f=d_f, d_e=d_e, dim=dim)

    # Quadratic stencil: nu/shift enter as runtime scalars so one artifact
    # serves every worker.
    d = args.quad_d
    lowered = lower(
        model.quad_gradient,
        f32((d,)), f32((d,)), f32(()), f32(()),
    )
    write(args.out_dir, "quad_grad", to_hlo_text(lowered), manifest,
          kind="quadratic", d=d)

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        for name, meta in manifest:
            for k, v in meta.items():
                f.write(f"{name}.{k} = {v}\n")
    print(f"wrote {len(manifest)} artifacts + manifest to {args.out_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
